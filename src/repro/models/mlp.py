"""Dense MLP variants: SwiGLU / GeGLU (fused gate+up), squared-ReLU, GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import activation_fn, is_gated
from repro.models.spec import ParamSpec


def mlp_specs(d_model: int, d_ff: int, activation: str, prefix_axes=()) -> dict:
    """Param specs for one dense MLP. ``prefix_axes`` prepends stacked-layer dims."""
    pshape = tuple(n for n, _ in prefix_axes)
    paxes = tuple(a for _, a in prefix_axes)
    if is_gated(activation):
        return {
            # fused [gate; up] projection, column-parallel over ffn
            "wi": ParamSpec(pshape + (d_model, 2 * d_ff), paxes + ("embed", "ffn"), "scaled"),
            "wo": ParamSpec(pshape + (d_ff, d_model), paxes + ("ffn", "embed"), "scaled"),
        }
    return {
        "wi": ParamSpec(pshape + (d_model, d_ff), paxes + ("embed", "ffn"), "scaled"),
        "wo": ParamSpec(pshape + (d_ff, d_model), paxes + ("ffn", "embed"), "scaled"),
    }


def mlp_forward(params: dict, x: jax.Array, activation: str) -> jax.Array:
    """x: (..., D) -> (..., D)."""
    h = jnp.einsum("...d,df->...f", x, params["wi"])
    if is_gated(activation):
        up, gate = jnp.split(h, 2, axis=-1)
        h = activation_fn(activation, up, gate)
    else:
        h = activation_fn(activation, h)
    return jnp.einsum("...f,fd->...d", h, params["wo"])
