"""Model zoo entry point."""
from __future__ import annotations

from typing import Optional

from repro.config import ModelConfig, ShardingConfig


def build_model(cfg: ModelConfig, mesh=None,
                sharding: Optional[ShardingConfig] = None,
                attn_impl: str = "auto", moe_impl: str = "auto",
                param_dtype: str = "", decode_impl: str = "auto"):
    """Construct the family-appropriate model object.

    Returned object exposes the uniform API used by the trainer, the serving
    engine and the dry-run: ``specs() / init(rng) / param_shapes() /
    input_specs(shape) / loss(params,batch) / forward(...) /
    prefill(params,batch,capacity) / decode_step(params,cache,batch) /
    init_cache(b,cap) / cache_specs(b,cap) / cache_axes(b,cap)``.
    """
    sharding = sharding or ShardingConfig()
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg, mesh=mesh, sharding=sharding,
                        attn_impl=attn_impl, param_dtype=param_dtype,
                        decode_impl=decode_impl)
    from repro.models.transformer import DecoderLM

    return DecoderLM(cfg, mesh=mesh, sharding=sharding, attn_impl=attn_impl,
                     moe_impl=moe_impl, param_dtype=param_dtype,
                     decode_impl=decode_impl)
