"""Mixture-of-Experts layer.

Two implementations sharing one param layout:

- ``dense``: every expert computes every token, outputs weighted by the router
  (correctness oracle; used for tiny smoke configs).
- ``dropping``: GShard-style capacity-bounded dispatch implemented with a
  sort-based scatter (NO (T, E, C) one-hot tensor is ever materialized) inside
  an expert-parallel ``shard_map``: tokens stay sharded over the data axis,
  experts are sharded over the model axis, each model shard dispatches the
  local tokens that picked its experts and partial outputs are combined with a
  single psum over the model axis. This is the production path: its working
  set per device is O(E_local * C * D), and the only collective it adds is the
  combine-psum (counted in §Roofline's collective term).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import activation_fn, is_gated
from repro.models.spec import ParamSpec

if hasattr(jax, "shard_map"):  # jax >= 0.7: top-level API, check_vma kwarg
    _shard_map = functools.partial(jax.shard_map, check_vma=False)
else:  # older jax: experimental module, replication check is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    _shard_map = functools.partial(_shard_map_legacy, check_rep=False)

if hasattr(jax.lax, "axis_size"):
    _axis_size = jax.lax.axis_size
else:  # older jax: derive the mesh-axis size via a collective
    def _axis_size(axis_name):
        return jax.lax.psum(1, axis_name)


def moe_specs(cfg: ModelConfig, prefix_axes=()) -> dict:
    """up ("wi") and gate ("wg") are SEPARATE tensors (not a fused 2F dim):
    fused layouts mis-split when the hidden dim is sharded over the model
    axis (2D expert parallelism / TP of the shared expert)."""
    pshape = tuple(n for n, _ in prefix_axes)
    paxes = tuple(a for _, a in prefix_axes)
    d, fe, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    specs = {
        "router": ParamSpec(pshape + (d, e), paxes + ("embed", "experts_r"), "small"),
        "wi": ParamSpec(pshape + (e, d, fe), paxes + ("experts", "embed", "expert_ffn"), "scaled"),
        "wo": ParamSpec(pshape + (e, fe, d), paxes + ("experts", "expert_ffn", "embed"), "scaled"),
    }
    if is_gated(cfg.activation):
        specs["wg"] = ParamSpec(pshape + (e, d, fe),
                                paxes + ("experts", "embed", "expert_ffn"),
                                "scaled")
    if cfg.num_shared_experts:
        fs = cfg.moe_d_ff * cfg.num_shared_experts
        specs["shared_wi"] = ParamSpec(pshape + (d, fs), paxes + ("embed", "ffn"), "scaled")
        specs["shared_wo"] = ParamSpec(pshape + (fs, d), paxes + ("ffn", "embed"), "scaled")
        if is_gated(cfg.activation):
            specs["shared_wg"] = ParamSpec(pshape + (d, fs),
                                           paxes + ("embed", "ffn"), "scaled")
    return specs


def _router_topk(x: jax.Array, router_w: jax.Array, top_k: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (T, D) -> (ids (T,k), weights (T,k) normalized, probs (T,E))."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)  # norm_topk_prob
    return ids, w, probs


def _aux_loss(probs: jax.Array, ids: jax.Array, num_experts: int) -> jax.Array:
    """Switch/GShard load-balance loss: E * sum_e f_e * p_e."""
    t = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(t * ids.shape[-1], 1)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def _expert_mlp(xb: jax.Array, wi: jax.Array, wo: jax.Array, activation: str,
                wg=None) -> jax.Array:
    """xb: (E, C, D); wi/wg: (E, D, F); wo: (E, F, D)."""
    up = jnp.einsum("ecd,edf->ecf", xb, wi)
    if is_gated(activation):
        gate = jnp.einsum("ecd,edf->ecf", xb, wg)
        h = activation_fn(activation, up, gate)
    else:
        h = activation_fn(activation, up)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _shared_expert(params: dict, x: jax.Array, activation: str) -> jax.Array:
    up = jnp.einsum("td,df->tf", x, params["shared_wi"])
    if is_gated(activation):
        gate = jnp.einsum("td,df->tf", x, params["shared_wg"])
        h = activation_fn(activation, up, gate)
    else:
        h = activation_fn(activation, up)
    return jnp.einsum("tf,fd->td", h, params["shared_wo"])


# ---------------------------------------------------------------------------
# dense oracle
# ---------------------------------------------------------------------------


def moe_dense_forward(params: dict, x: jax.Array, cfg: ModelConfig
                      ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Returns (y, aux_loss). Computes ALL experts (oracle)."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    ids, w, probs = _router_topk(xt, params["router"], cfg.top_k)
    # (T, E) combine weights from top-k selection
    comb = jnp.zeros((xt.shape[0], cfg.num_experts), x.dtype)
    comb = comb.at[jnp.arange(xt.shape[0])[:, None], ids].set(w.astype(x.dtype))
    xb = jnp.broadcast_to(xt[None], (cfg.num_experts,) + xt.shape)
    all_out = _expert_mlp(xb, params["wi"], params["wo"], cfg.activation,
                          params.get("wg"))  # (E, T, D)
    y = jnp.einsum("te,etd->td", comb, all_out)
    if cfg.num_shared_experts:
        y = y + _shared_expert(params, xt, cfg.activation)
    return y.reshape(b, s, d), _aux_loss(probs, ids, cfg.num_experts)


# ---------------------------------------------------------------------------
# dropping (sort-based, expert-parallel)
# ---------------------------------------------------------------------------


def _dispatch_plan(ids: jax.Array, w: jax.Array, e_lo: int, e_local: int,
                   capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Slot-centric dispatch plan (only index/weight vectors — O(T*k) ints,
    never an (T*k, D) tensor).

    ids/w: (T, k). Returns per-SLOT vectors of length E_l*C:
      slot_src  — source token index (0 for empty slots),
      slot_w    — combine weight (0 for empty slots),
      slot_valid— bool mask.
    """
    t, k = ids.shape
    n = t * k
    flat_ids = ids.reshape(-1) - e_lo
    tok_idx = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    w_flat = w.reshape(-1)
    is_local = (flat_ids >= 0) & (flat_ids < e_local)
    sort_key = jnp.where(is_local, flat_ids, e_local)
    order = jnp.argsort(sort_key, stable=True)
    sorted_ids = sort_key[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(e_local), side="left")
    pos = jnp.arange(n) - jnp.where(
        sorted_ids < e_local,
        starts[jnp.minimum(sorted_ids, e_local - 1)], 0)
    valid = (sorted_ids < e_local) & (pos < capacity)
    dest = jnp.where(valid, sorted_ids * capacity + pos, e_local * capacity)
    nslots = e_local * capacity
    slot_src = jnp.zeros((nslots + 1,), jnp.int32).at[dest].set(
        tok_idx[order], mode="drop")[:-1]
    slot_w = jnp.zeros((nslots + 1,), w_flat.dtype).at[dest].set(
        jnp.where(valid, w_flat[order], 0.0), mode="drop")[:-1]
    slot_valid = jnp.zeros((nslots + 1,), jnp.bool_).at[dest].set(
        valid, mode="drop")[:-1]
    return slot_src, slot_w, slot_valid


def _dispatch_gather(xt: jax.Array, slot_src: jax.Array,
                     slot_valid: jax.Array, e_local: int, capacity: int
                     ) -> jax.Array:
    """(T, D) tokens -> (E_l, C, D) buffers; empty slots zeroed."""
    x_buf = xt[slot_src] * slot_valid[:, None].astype(xt.dtype)
    return x_buf.reshape(e_local, capacity, -1)


def _combine_scatter(y_buf: jax.Array, slot_src: jax.Array, slot_w: jax.Array,
                     t: int) -> jax.Array:
    """(E_l, C, D) expert outputs -> (T, D) weighted scatter-add."""
    d = y_buf.shape[-1]
    contrib = y_buf.reshape(-1, d) * slot_w[:, None].astype(y_buf.dtype)
    return jnp.zeros((t, d), y_buf.dtype).at[slot_src].add(contrib)


def moe_dropping_local(params: dict, xt: jax.Array, cfg: ModelConfig,
                       model_axis: Optional[str], data_axis) -> Tuple[jax.Array, jax.Array]:
    """Per-shard body (called inside shard_map, or standalone when axes None).

    xt: (T_local, D) tokens local to this data shard, replicated over model.
    Expert weights arrive sliced over the model axis: (E_local, D, F).
    """
    t, d = xt.shape
    e_local = params["wi"].shape[0]
    tp = 1
    e_lo = 0
    if model_axis is not None:
        tp = _axis_size(model_axis)
        e_lo = jax.lax.axis_index(model_axis) * e_local
    num_experts = e_local * tp
    ids, w, probs = _router_topk(xt, params["router"], cfg.top_k)
    capacity = max(1, int(t * cfg.top_k / num_experts * cfg.capacity_factor))
    slot_src, slot_w, slot_valid = _dispatch_plan(ids, w, e_lo, e_local,
                                                  capacity)
    x_buf = _dispatch_gather(xt, slot_src, slot_valid, e_local, capacity)
    y_buf = _expert_mlp(x_buf, params["wi"], params["wo"], cfg.activation,
                        params.get("wg"))
    y = _combine_scatter(y_buf, slot_src, slot_w, t)
    if cfg.num_shared_experts:
        # shared expert ffn dim is sharded over model -> partial sums psum below
        y = y + _shared_expert(params, xt, cfg.activation)
        if model_axis is not None:
            # shared ffn slice produced a partial (1/tp) result; psum merges it
            # together with the routed-expert partials in one collective
            pass
    aux = _aux_loss(probs, ids, num_experts)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y, aux


def moe_dropping_forward(params: dict, x: jax.Array, cfg: ModelConfig,
                         mesh, model_axis="model", data_axis="data",
                         batch_axes=("data",)) -> Tuple[jax.Array, jax.Array]:
    """shard_map wrapper: tokens sharded over data (+pod), experts over model."""
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    pod = ("pod",) if "pod" in mesh.axis_names else ()
    batch_spec = tuple(pod + (data_axis,))

    def body(xt, router, wi, wg, wo, shared):
        p = {"router": router, "wi": wi, "wo": wo}
        if wg is not None:
            p["wg"] = wg
        if shared is not None:
            p.update(shared)
        y, aux = moe_dropping_local(p, xt.reshape(-1, d), cfg, model_axis, None)
        if pod or data_axis:  # average aux over token shards
            y_axes = tuple(a for a in (pod + (data_axis,)) if a)
            aux = jax.lax.pmean(aux, y_axes)
        return y.reshape(xt.shape), aux

    shared = None
    shared_spec = None
    if cfg.num_shared_experts:
        shared = {"shared_wi": params["shared_wi"],
                  "shared_wo": params["shared_wo"]}
        shared_spec = {"shared_wi": P(None, model_axis),
                       "shared_wo": P(model_axis, None)}
        if "shared_wg" in params:
            shared["shared_wg"] = params["shared_wg"]
            shared_spec["shared_wg"] = P(None, model_axis)
    wg = params.get("wg")
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(batch_spec, None, None),          # x
                  P(None, None),                       # router replicated
                  P(model_axis, None, None),           # wi expert-sharded
                  None if wg is None else P(model_axis, None, None),
                  P(model_axis, None, None),           # wo
                  shared_spec,
                  ),
        out_specs=(P(batch_spec, None, None), P()),
    )
    return fn(x, params["router"], params["wi"], wg, params["wo"], shared)


# ---------------------------------------------------------------------------
# decode-2D: weight-stationary expert parallelism for small token counts
# ---------------------------------------------------------------------------


def moe_decode_2d_local(params: dict, xt: jax.Array, cfg: ModelConfig,
                        data_axis: str, model_axis: str) -> jax.Array:
    """Per-shard body: experts sharded over DATA, expert-FFN over MODEL,
    tokens REPLICATED. No weight ever moves; the only collective is the
    psum of the (tiny) combined activations over both axes.

    Right for decode: T = global_batch tokens/step, so activations are ~MBs
    while a 1T MoE's weights are TBs — the per-step all-gather of ZeRO-style
    sharded weights that dominates naive decode disappears entirely.
    """
    t, d = xt.shape
    e_local = params["wi"].shape[0]
    dp = _axis_size(data_axis)
    e_lo = jax.lax.axis_index(data_axis) * e_local
    num_experts = e_local * dp
    ids, w, _ = _router_topk(xt, params["router"], cfg.top_k)
    capacity = max(1, int(t * cfg.top_k / num_experts * cfg.capacity_factor)
                   + 1)
    slot_src, slot_w, slot_valid = _dispatch_plan(ids, w, e_lo, e_local,
                                                  capacity)
    x_buf = _dispatch_gather(xt, slot_src, slot_valid, e_local, capacity)
    # expert FFN with the hidden dim sharded over the model axis: gate and
    # up slices align, the down-projection contracts the local F slice ->
    # every shard holds a PARTIAL (over model) of its experts' outputs
    y_buf = _expert_mlp(x_buf, params["wi"], params["wo"], cfg.activation,
                        params.get("wg"))
    y = _combine_scatter(y_buf, slot_src, slot_w, t)
    return jax.lax.psum(y, (data_axis, model_axis))


def moe_decode_2d_forward(params: dict, x: jax.Array, cfg: ModelConfig,
                          mesh) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape

    def body(xt, router, wi, wg, wo):
        p = {"router": router, "wi": wi, "wo": wo}
        if wg is not None:
            p["wg"] = wg
        y = moe_decode_2d_local(p, xt.reshape(-1, d), cfg, "data", "model")
        return y.reshape(b, s, d)

    wg = params.get("wg")
    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None),        # tokens replicated (tiny)
                  P(None, None),               # router replicated
                  P("data", None, "model"),    # wi: E over data, F over model
                  None if wg is None else P("data", None, "model"),
                  P("data", "model", None)),   # wo
        out_specs=P(None, None, None))
    y = fn(x, params["router"], params["wi"], wg, params["wo"])
    if cfg.num_shared_experts:
        # shared expert outside the shard_map (plain TP einsum, XLA handles)
        ys = _shared_expert(params, x.reshape(-1, d), cfg.activation)
        y = y + ys.reshape(b, s, d)
    return y, jnp.zeros((), jnp.float32)


def moe_forward(params: dict, x: jax.Array, cfg: ModelConfig, mesh=None,
                impl: str = "auto") -> Tuple[jax.Array, jax.Array]:
    if impl == "auto":
        impl = "dropping" if mesh is not None else "dense"
    if impl == "decode2d":
        if mesh is None:  # single-device fallback: same math, no collectives
            b, s, d = x.shape
            y, aux = moe_dropping_local(params, x.reshape(-1, d), cfg,
                                        None, None)
            return y.reshape(b, s, d), aux
        return moe_decode_2d_forward(params, x, cfg, mesh)
    if impl == "dense":
        return moe_dense_forward(params, x, cfg)
    if impl == "dropping":
        if mesh is None:
            b, s, d = x.shape
            y, aux = moe_dropping_local(params, x.reshape(-1, d), cfg, None, None)
            return y.reshape(b, s, d), aux
        return moe_dropping_forward(params, x, cfg, mesh)
    raise ValueError(impl)
