"""Attention implementations: dense oracle, XLA flash (chunked online-softmax),
exact block-local sliding window, and cache decode. All GQA-aware.

The Pallas TPU kernels in ``repro.kernels`` implement the same contracts; the
XLA paths here are the lowering default (and the correctness oracles).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _split_gqa(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, K, G, hd)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def _merge_gqa(o: jax.Array) -> jax.Array:
    b, s, k, g, d = o.shape
    return o.reshape(b, s, k * g, d)


def _largest_divisor_leq(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (for ragged VLM sequences)."""
    target = min(target, n)
    for c in range(target, 0, -1):
        if n % c == 0:
            return c
    return 1


def _mask_bias(pos_q: jax.Array, pos_kv: jax.Array, causal: bool,
               window: Optional[int]) -> jax.Array:
    """Additive mask bias (..., Sq, Skv) from absolute positions.

    pos_q: (B, Sq) or (Sq,); pos_kv: (B, Skv) or (Skv,). kv positions < 0
    denote empty cache slots and are always masked.
    """
    if pos_q.ndim == 1:
        pos_q = pos_q[None]
    if pos_kv.ndim == 1:
        pos_kv = pos_kv[None]
    d = pos_q[:, :, None] - pos_kv[:, None, :]  # (B, Sq, Skv)
    ok = pos_kv[:, None, :] >= 0
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    pos_q: jax.Array, pos_kv: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    ) -> jax.Array:
    """Reference attention, fully materialized scores. GQA via K grouping.

    q: (B, Sq, H, hd); k/v: (B, Skv, K, hd). Returns (B, Sq, H, hd).
    """
    num_kv = k.shape[2]
    qg = _split_gqa(q, num_kv)  # (B,Sq,K,G,hd)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    bias = _mask_bias(pos_q, pos_kv, causal, window)  # (B,Sq,Skv)
    s = s + bias[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return _merge_gqa(o).astype(q.dtype)


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array,
                        pos_q: jax.Array, pos_kv: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        q_chunk: int = 2048, kv_chunk: int = 2048,
                        ) -> jax.Array:
    """Online-softmax attention, O(q_chunk*kv_chunk) score memory.

    Python-unrolled over Q chunks so causal chunk-skipping is STATIC: for
    query chunk i only kv chunks 0..i are touched -> HLO FLOPs ~ the true
    causal half, not the dense square (matters for §Roofline usefulness).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    num_kv = k.shape[2]
    scale = d ** -0.5
    q_chunk = _largest_divisor_leq(sq, q_chunk)
    kv_chunk = _largest_divisor_leq(skv, kv_chunk)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)
    n_q, n_kv = sq // q_chunk, skv // kv_chunk
    if pos_q.ndim == 1:
        pos_q = jnp.broadcast_to(pos_q[None], (b, sq))
    if pos_kv.ndim == 1:
        pos_kv = jnp.broadcast_to(pos_kv[None], (b, skv))

    qg = _split_gqa(q, num_kv)  # (B,Sq,K,G,hd)

    outs = []
    for qi in range(n_q):
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        pq_blk = jax.lax.dynamic_slice_in_dim(pos_q, qi * q_chunk, q_chunk, axis=1)
        q_blk = q_blk.astype(jnp.float32)

        # static causal skip: kv chunks beyond the diagonal never touched
        hi = (qi + 1) if causal else n_kv
        # static window skip: kv chunks entirely before the window (only valid
        # for self-attention layouts where pos == index; callers with caches
        # pass window masking via positions anyway, so this is a safe bound)
        lo = 0
        if window is not None and causal and sq == skv and q_chunk == kv_chunk:
            lo = max(0, qi - (window + q_chunk - 1) // kv_chunk)

        def kv_step(carry, ki):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            pkv_blk = jax.lax.dynamic_slice_in_dim(pos_kv, ki * kv_chunk, kv_chunk, axis=1)
            # K/V stay in model dtype; scores accumulate f32 (no f32 copies
            # of the K/V blocks), probabilities travel to the PV matmul in
            # the model dtype (flash-standard; final acc stays f32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk.astype(k.dtype), k_blk,
                           preferred_element_type=jnp.float32) * scale
            bias = _mask_bias(pq_blk, pkv_blk, causal, window)
            s = s + bias[:, None, None, :, :]
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v.dtype), v_blk,
                preferred_element_type=jnp.float32)
            l = l * corr + jnp.sum(p, axis=-1)
            return (acc, m_new, l), None

        g = h // num_kv
        acc0 = jnp.zeros((b, num_kv, g, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, num_kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, num_kv, g, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(lo, hi))
        o = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,K,G,qc,hd)
        outs.append(jnp.transpose(o, (0, 3, 1, 2, 4)))  # (B,qc,K,G,hd)
    out = jnp.concatenate(outs, axis=1)
    return _merge_gqa(out).astype(q.dtype)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    pos: jax.Array, *, window: int) -> jax.Array:
    """Exact causal sliding-window self-attention via block-local computation.

    Each token attends to the previous ``window`` tokens (inclusive of self).
    Blocks of size ``window`` attend to (self, previous) block only -> cost
    O(S * 2W) instead of O(S^2). q: (B,S,H,hd), k/v: (B,S,K,hd).
    """
    b, s, h, d = q.shape
    num_kv = k.shape[2]
    w = min(window, s)
    pad = (-s) % w
    if pad:
        padf = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v = padf(q), padf(k), padf(v)
        if pos.ndim == 1:
            pos = jnp.pad(pos, (0, pad), constant_values=-1)
        else:
            pos = jnp.pad(pos, ((0, 0), (0, pad)), constant_values=-1)
    sp = q.shape[1]
    nb = sp // w
    if pos.ndim == 1:
        pos = jnp.broadcast_to(pos[None], (b, sp))

    qb = _split_gqa(q, num_kv).reshape(b, nb, w, num_kv, h // num_kv, d)
    kb = k.reshape(b, nb, w, num_kv, d)
    vb = v.reshape(b, nb, w, num_kv, d)
    pb = pos.reshape(b, nb, w)

    # previous block (block 0's previous is all-masked via position -1)
    prev = lambda x, fill: jnp.concatenate(
        [jnp.full_like(x[:, :1], fill), x[:, :-1]], axis=1)
    k2 = jnp.concatenate([prev(kb, 0.0), kb], axis=2)      # (B,nb,2w,K,hd)
    v2 = jnp.concatenate([prev(vb, 0.0), vb], axis=2)
    p2 = jnp.concatenate([prev(pb, -1), pb], axis=2)        # (B,nb,2w)

    scale = d ** -0.5
    sco = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb.astype(jnp.float32),
                     k2.astype(jnp.float32)) * scale
    diff = pb[:, :, :, None] - p2[:, :, None, :]  # (B,nb,w,2w)
    ok = (p2[:, :, None, :] >= 0) & (diff >= 0) & (diff < w)
    sco = sco + jnp.where(ok, 0.0, NEG_INF)[:, :, None, None, :, :]
    prob = jax.nn.softmax(sco, axis=-1)
    o = jnp.einsum("bnkgqs,bnskd->bnqkgd", prob, v2.astype(jnp.float32))
    o = o.reshape(b, sp, h, d)[:, :s]
    return o.astype(q.dtype)


def decode_attention_xla(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         pos_q: jax.Array, pos_cache: jax.Array, *,
                         window: Optional[int] = None) -> jax.Array:
    """One-token decode against a (possibly ring-buffer) KV cache.

    q: (B, 1, H, hd); k/v_cache: (B, T, K, hd); pos_q: (B,) current absolute
    position; pos_cache: (B, T) absolute position per slot (-1 = empty).
    """
    b, _, h, d = q.shape
    num_kv = k_cache.shape[2]
    qg = q[:, 0].reshape(b, num_kv, h // num_kv, d)  # (B,K,G,hd), q dim dropped
    scale = d ** -0.5
    # keep the (huge) cache in bf16 and accumulate in f32 — an explicit
    # astype would materialize (and reshard) an f32 copy of the whole cache.
    # Contracting with the cache's native (B,T,K,hd) layout (no q axis)
    # avoids the transposed-copy the previous bkgqt form paid per call.
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(k_cache.dtype), k_cache,
                   preferred_element_type=jnp.float32) * scale
    dd = pos_q[:, None] - pos_cache  # (B,T)
    ok = (pos_cache >= 0) & (dd >= 0)
    if window is not None:
        ok = ok & (dd < window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgt,btkd->bkgd",
                   (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, d).astype(q.dtype)


def decode_attention_paged_xla(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, pages: jax.Array,
                               pos_q: jax.Array, pos_cache: jax.Array, *,
                               window: Optional[int] = None) -> jax.Array:
    """One-token decode through a page table into a shared physical pool.

    q: (B, 1, H, hd); k/v_pool: (P, page, K, hd) physical pages (page 0 is
    the engine's null page); pages: (B, NP) int32 page table covering the
    attended context; pos_q: (B,); pos_cache: (B, T<=NP*page) absolute
    position per LOGICAL row (-1 = empty/unwritten — null-page garbage is
    masked here, which is what makes unmapped entries safe to gather).

    The gather materializes each row's attended context (the same bytes the
    attention contraction reads anyway) and then defers to the dense decode
    path SLICED to ``pos_cache``'s width — so paged and dense decode see
    byte-identical operands and reduce in the same order: token-for-token
    parity is by construction, not by tolerance.
    """
    b, np_ = pages.shape
    page = k_pool.shape[1]
    t = pos_cache.shape[1]
    # (B, NP, page, K, hd) -> (B, NP*page, K, hd), sliced to the exact
    # context width the dense path would attend
    k_ctx = k_pool[pages].reshape(b, np_ * page, *k_pool.shape[2:])[:, :t]
    v_ctx = v_pool[pages].reshape(b, np_ * page, *v_pool.shape[2:])[:, :t]
    return decode_attention_xla(q, k_ctx, v_ctx, pos_q, pos_cache,
                                window=window)


def decode_attention_paged(q, k_pool, v_pool, pages, pos_q, pos_cache, *,
                           window: Optional[int] = None, impl: str = "auto"):
    """Paged decode dispatcher. impl: auto | xla | pallas.

    "pallas" streams pages straight out of the pool via a scalar-prefetched
    page table (no gathered copy of the context); "xla" gathers then reuses
    the dense decode path (bit-identical to dense serving).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.decode_attention_paged(q, k_pool, v_pool, pages, pos_q,
                                           pos_cache, window=window)
    if impl == "xla":
        return decode_attention_paged_xla(q, k_pool, v_pool, pages, pos_q,
                                          pos_cache, window=window)
    raise ValueError(impl)


def decode_attention(q, k_cache, v_cache, pos_q, pos_cache, *,
                     window: Optional[int] = None, impl: str = "auto"):
    """Decode dispatcher. impl: auto | xla | pallas.

    "auto" picks the Pallas flash-decode kernel where it compiles natively
    (TPU) and the fused XLA path elsewhere (interpret-mode Pallas would run
    the kernel body in Python per block — far slower than XLA on CPU).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.decode_attention(q, k_cache, v_cache, pos_q, pos_cache,
                                     window=window)
    if impl == "xla":
        return decode_attention_xla(q, k_cache, v_cache, pos_q, pos_cache,
                                    window=window)
    raise ValueError(impl)


def attention(q, k, v, pos_q, pos_kv, *, causal=True, window=None,
              impl="auto", q_chunk=2048, kv_chunk=2048):
    """Dispatcher. impl: auto | dense | flash | local | pallas."""
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if impl == "auto":
        sq, skv = q.shape[1], k.shape[1]
        if window is not None and causal and sq == skv and sq > window:
            impl = "local"
        elif sq * skv <= 4096 * 4096 // 4:
            impl = "dense"
        else:
            impl = "flash"
    if impl == "dense":
        return dense_attention(q, k, v, pos_q, pos_kv, causal=causal, window=window)
    if impl == "local":
        return local_attention(q, k, v, pos_q, window=window)
    if impl == "flash":
        return flash_attention_xla(q, k, v, pos_q, pos_kv, causal=causal,
                                   window=window, q_chunk=q_chunk, kv_chunk=kv_chunk)
    raise ValueError(impl)
