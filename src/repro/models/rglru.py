"""RG-LRU recurrent block (Griffin / RecurrentGemma [arXiv:2402.19427]).

Recurrence: a_t = exp(-c * softplus(Λ) * r_t),  r_t, i_t input-dependent gates,
h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t).

Train/prefill uses ``jax.lax.associative_scan`` (O(S log S), parallel, exact);
decode is an O(1) state update — this is what makes the hybrid arch
``long_500k``-eligible.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.spec import ParamSpec

_C = 8.0  # Griffin's fixed gate sharpness constant


def rglru_specs(cfg: ModelConfig, prefix_axes=()) -> dict:
    ps = tuple(n for n, _ in prefix_axes)
    pa = tuple(a for _, a in prefix_axes)
    d = cfg.d_model
    w = cfg.lru_width or cfg.d_model
    cw = cfg.ssm_conv or 4
    return {
        "ln": ParamSpec(ps + (d,), pa + ("embed",), "zeros"),
        "wg": ParamSpec(ps + (d, w), pa + ("embed", "heads"), "scaled"),  # gelu branch
        "wx": ParamSpec(ps + (d, w), pa + ("embed", "heads"), "scaled"),  # recurrent branch
        "conv_w": ParamSpec(ps + (cw, w), pa + (None, "heads"), "scaled"),
        "conv_b": ParamSpec(ps + (w,), pa + ("heads",), "zeros"),
        "w_r": ParamSpec(ps + (w, w), pa + ("heads_in", "heads"), "scaled"),
        "b_r": ParamSpec(ps + (w,), pa + ("heads",), "zeros"),
        "w_i": ParamSpec(ps + (w, w), pa + ("heads_in", "heads"), "scaled"),
        "b_i": ParamSpec(ps + (w,), pa + ("heads",), "zeros"),
        "lam": ParamSpec(ps + (w,), pa + ("heads",), "ones"),  # Λ
        "wo": ParamSpec(ps + (w, d), pa + ("heads", "embed"), "scaled"),
    }


def _gates(params: dict, xr: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """a_t (log-space) and scaled input. xr: (B,S,W) float32."""
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xr, params["w_r"].astype(jnp.float32))
                       + params["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xr, params["w_i"].astype(jnp.float32))
                       + params["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r  # (B,S,W) <= 0
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * xr)
    return a, gated_x


def _lru_scan(a: jax.Array, b: jax.Array, h0: Optional[jax.Array]
              ) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t h_{t-1} + b_t via associative scan over axis=1.

    a, b: (B,S,W) float32. Returns (h (B,S,W), h_last (B,W)).
    """
    if h0 is not None:
        # fold the incoming state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1, :]


def rglru_forward(params: dict, x: jax.Array, cfg: ModelConfig, *,
                  conv_state: Optional[jax.Array] = None,
                  h_state: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, dict]:
    """Full-sequence Griffin recurrent block. x: (B,S,D)."""
    from repro.models.ssm import _causal_conv

    res = x
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, params["wg"]))
    xr = jnp.einsum("bsd,dw->bsw", xn, params["wx"])
    xr, new_conv = _causal_conv(xr, params["conv_w"], params["conv_b"], conv_state)
    a, gx = _gates(params, xr.astype(jnp.float32))
    h, h_last = _lru_scan(a, gx, h_state)
    y = (g.astype(jnp.float32) * h).astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", y, params["wo"])
    return res + out, {"conv": new_conv, "h": h_last}


def rglru_decode(params: dict, x: jax.Array, cfg: ModelConfig, cache: dict
                 ) -> Tuple[jax.Array, dict]:
    """Single-token decode. x: (B,1,D); cache {"conv": (B,cw-1,W), "h": (B,W)}."""
    from repro.models.ssm import _causal_conv

    res = x
    xn = rms_norm(x, params["ln"], cfg.norm_eps)
    g = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", xn, params["wg"]))
    xr = jnp.einsum("bsd,dw->bsw", xn, params["wx"])
    xr, new_conv = _causal_conv(xr, params["conv_w"], params["conv_b"],
                                cache["conv"])
    a, gx = _gates(params, xr.astype(jnp.float32))
    h = a[:, 0] * cache["h"] + gx[:, 0]  # (B,W)
    y = (g[:, 0].astype(jnp.float32) * h).astype(x.dtype)[:, None, :]
    out = jnp.einsum("bsw,wd->bsd", y, params["wo"])
    return res + out, {"conv": new_conv, "h": h}


def rglru_cache_shape(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    cw = cfg.ssm_conv or 4
    return {"conv": (batch, cw - 1, w), "h": (batch, w)}
