"""Encoder-decoder LM (Whisper-small family).

Encoder: bidirectional attention over precomputed audio-frame embeddings
(conv frontend is a STUB per the assignment — ``input_specs`` supplies mel
frames, a linear projection stands in for the two conv1d layers).
Decoder: causal self-attention (RoPE; the original uses learned positions —
documented deviation) + cross-attention into the encoder output.

Whisper uses LayerNorm (with bias); attention/MLP biases are omitted
(documented deviation, immaterial for systems purposes).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig, ShardingConfig
from repro.distributed.sharding import logical_constraint
from repro.models import attention as attn_mod
from repro.models.layers import (apply_rope, embed, layer_norm, pad_vocab,
                                 rope_freqs, unembed)
from repro.models.mlp import mlp_forward, mlp_specs
from repro.models.spec import ParamSpec, init_tree, shape_tree
from repro.models.transformer import _remat


def _ln_specs(d, ps, pa):
    return {"w": ParamSpec(ps + (d,), pa + ("embed",), "ones"),
            "b": ParamSpec(ps + (d,), pa + ("embed",), "zeros")}


def _attn_proj_specs(cfg, ps, pa):
    d, h, k, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": ParamSpec(ps + (d, h * hd), pa + ("embed", "heads"), "scaled"),
        "wk": ParamSpec(ps + (d, k * hd), pa + ("embed", "kv_heads"), "scaled"),
        "wv": ParamSpec(ps + (d, k * hd), pa + ("embed", "kv_heads"), "scaled"),
        "wo": ParamSpec(ps + (h * hd, d), pa + ("heads", "embed"), "scaled"),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig, mesh=None,
                 sharding: ShardingConfig = ShardingConfig(),
                 attn_impl: str = "auto", param_dtype: str = "",
                 decode_impl: str = "auto"):
        assert cfg.family == "encdec"
        self.cfg = cfg
        self.mesh = mesh
        self.sharding = sharding
        self.attn_impl = attn_impl
        self.decode_impl = decode_impl
        self.v_pad = pad_vocab(cfg.vocab_size)
        self.dtype = jnp.dtype(param_dtype or cfg.dtype)

    # ------------------------------------------------------------------

    def specs(self) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        el, dl = cfg.encoder_layers, cfg.num_layers
        enc_block = {
            "ln1": _ln_specs(d, (el,), ("layers",)),
            "attn": _attn_proj_specs(cfg, (el,), ("layers",)),
            "ln2": _ln_specs(d, (el,), ("layers",)),
            "mlp": mlp_specs(d, cfg.d_ff, cfg.activation, ((el, "layers"),)),
        }
        dec_block = {
            "ln1": _ln_specs(d, (dl,), ("layers",)),
            "self_attn": _attn_proj_specs(cfg, (dl,), ("layers",)),
            "ln_x": _ln_specs(d, (dl,), ("layers",)),
            "cross_attn": _attn_proj_specs(cfg, (dl,), ("layers",)),
            "ln2": _ln_specs(d, (dl,), ("layers",)),
            "mlp": mlp_specs(d, cfg.d_ff, cfg.activation, ((dl, "layers"),)),
        }
        return {
            "proj_in": ParamSpec((cfg.frontend_dim, d), ("frontend", "embed"),
                                 "scaled"),
            "enc_pos": ParamSpec((cfg.encoder_seq, d), (None, "embed"), "normal"),
            "enc_blocks": enc_block,
            "enc_ln_f": _ln_specs(d, (), ()),
            "embed": ParamSpec((self.v_pad, d), ("vocab", "embed"), "normal"),
            "dec_blocks": dec_block,
            "dec_ln_f": _ln_specs(d, (), ()),
        }

    def init(self, rng):
        return init_tree(self.specs(), rng, self.dtype)

    def param_shapes(self):
        return shape_tree(self.specs(), self.dtype)

    def input_specs(self, shape: ShapeConfig) -> Tuple[dict, dict]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        frames = jax.ShapeDtypeStruct((b, cfg.encoder_seq, cfg.frontend_dim),
                                      jnp.float32)
        if shape.kind in ("train", "prefill"):
            specs = {"frames": frames,
                     "tokens": jax.ShapeDtypeStruct((b, s), i32)}
            axes = {"frames": ("batch", None, "frontend"),
                    "tokens": ("batch", "seq")}
            if shape.kind == "train":
                specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
                axes["labels"] = ("batch", "seq")
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                     "positions": jax.ShapeDtypeStruct((b,), i32)}
            axes = {"tokens": ("batch", "seq"), "positions": ("batch",)}
        return specs, axes

    # ------------------------------------------------------------------

    def _constrain(self, x, axes):
        return logical_constraint(x, axes, self.mesh)

    def _mha(self, lp, xq, xkv, pos_q, pos_kv, causal, mode="full",
             lcache=None, idx=None):
        cfg = self.cfg
        b, sq, d = xq.shape
        h_, k_, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        q = jnp.einsum("bsd,dh->bsh", xq, lp["wq"]).reshape(b, sq, h_, hd)
        if mode == "cross_cached":
            k, v = lcache["k"], lcache["v"]
        else:
            skv = xkv.shape[1]
            k = jnp.einsum("bsd,dh->bsh", xkv, lp["wk"]).reshape(b, skv, k_, hd)
            v = jnp.einsum("bsd,dh->bsh", xkv, lp["wv"]).reshape(b, skv, k_, hd)
        if causal and mode != "cross_cached":  # RoPE on decoder self-attn only
            cos, sin = rope_freqs(pos_q, hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            if mode != "decode":
                k = apply_rope(k, cos, sin)
            else:
                cosk, sink = rope_freqs(pos_q, hd, cfg.rope_theta)
                k = apply_rope(k, cosk, sink)
        new_cache = lcache
        if mode == "decode":
            bi = jnp.arange(b)
            kc = lcache["k"].at[bi, idx].set(k[:, 0].astype(lcache["k"].dtype))
            vc = lcache["v"].at[bi, idx].set(v[:, 0].astype(lcache["v"].dtype))
            out = attn_mod.decode_attention(q, kc, vc, pos_q[:, 0], pos_kv,
                                            impl=self.decode_impl)
            new_cache = {"k": kc, "v": vc}
        elif mode == "cross_cached":
            out = attn_mod.decode_attention(
                q, k, v, jnp.full((b,), 10**9, jnp.int32), pos_kv,
                impl=self.decode_impl)
        else:
            out = attn_mod.attention(q, k, v, pos_q, pos_kv, causal=causal,
                                     impl=self.attn_impl)
            if mode == "prefill":
                new_cache = {"k": k, "v": v}
        o = jnp.einsum("bsh,hd->bsd", out.reshape(b, sq, h_ * hd), lp["wo"])
        return o.astype(xq.dtype), new_cache

    def encode(self, params, frames):
        cfg = self.cfg
        x = jnp.einsum("bsf,fd->bsd", frames.astype(self.dtype),
                       params["proj_in"])
        x = x + params["enc_pos"][None].astype(self.dtype)
        x = self._constrain(x, ("batch", "seq", "embed"))
        b, s, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(x, lp):
            h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
            o, _ = self._mha(lp["attn"], h, h, pos, pos, causal=False)
            x = x + o
            h = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
            x = x + mlp_forward(lp["mlp"], h, cfg.activation).astype(x.dtype)
            return x, None

        x, _ = jax.lax.scan(_remat(body, self.sharding.remat_policy),
                            x, params["enc_blocks"])
        return layer_norm(x, params["enc_ln_f"]["w"], params["enc_ln_f"]["b"],
                          cfg.norm_eps)

    def _decode_stack(self, params, x, enc_out, mode, cache):
        cfg = self.cfg
        b, s, _ = x.shape
        if mode == "decode":
            pos = cache["positions_now"]  # (B,1), injected by decode_step
        else:
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        enc_pos = None
        if enc_out is not None:
            se = enc_out.shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None],
                                       (b, se))
        idx = cache["index"] if (cache and "index" in cache) else None
        pos_kv = cache["pos"] if (cache and "pos" in cache) else None

        def body(carry, xs):
            x = carry
            lp, lc = xs
            h = layer_norm(x, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
            if mode == "decode":
                o, nc_self = self._mha(lp["self_attn"], h, h, pos, pos_kv,
                                       True, "decode", lc["self"], idx)
            else:
                o, nc_self = self._mha(lp["self_attn"], h, h, pos, pos, True,
                                       "prefill" if mode == "prefill" else "full")
            x = x + o
            h = layer_norm(x, lp["ln_x"]["w"], lp["ln_x"]["b"], cfg.norm_eps)
            if mode == "decode":
                o, _ = self._mha(lp["cross_attn"], h, None, pos,
                                 lc["cross_pos"], False, "cross_cached",
                                 lc["cross"])
                nc_cross = lc["cross"]
            else:
                o, nc_cross = self._mha(
                    lp["cross_attn"], h, enc_out, pos, enc_pos, False,
                    "prefill" if mode == "prefill" else "full")
            x = x + o
            h = layer_norm(x, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
            x = x + mlp_forward(lp["mlp"], h, cfg.activation).astype(x.dtype)
            ys = None
            if mode == "prefill":
                ys = {"self": nc_self, "cross": nc_cross}
            elif mode == "decode":
                ys = {"self": nc_self, "cross": lc["cross"]}
            return x, ys

        policy = self.sharding.remat_policy if mode == "train" else "none"
        if mode == "decode":
            lc_tree = {"self": cache["self"], "cross": cache["cross"],
                       "cross_pos": None}
            # cross_pos is shared (not stacked): close over it
            cross_pos = cache["cross_pos"]

            def body2(carry, xs):
                lp, lc = xs
                lc = dict(lc)
                lc["cross_pos"] = cross_pos
                return body(carry, (lp, lc))
            x, ys = jax.lax.scan(body2, x,
                                 (params["dec_blocks"],
                                  {"self": cache["self"], "cross": cache["cross"]}))
        else:
            x, ys = jax.lax.scan(
                _remat(lambda c, lp: body(c, (lp, None)), policy),
                x, params["dec_blocks"])
        x = layer_norm(x, params["dec_ln_f"]["w"], params["dec_ln_f"]["b"],
                       cfg.norm_eps)
        return x, ys

    # ------------------------------------------------------------------

    def loss(self, params, batch):
        enc = self.encode(params, batch["frames"])
        x = embed(batch["tokens"], params["embed"]).astype(self.dtype)
        x = self._constrain(x, ("batch", "seq", "embed"))
        x, _ = self._decode_stack(params, x, enc, "train", None)
        labels = batch["labels"]
        mask = jnp.ones_like(labels, jnp.float32)
        # chunked CE (same scheme as DecoderLM)
        from repro.models.transformer import DecoderLM
        ce = DecoderLM._chunked_ce(self, x, params["embed"], labels, mask)
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def prefill(self, params, batch, capacity: int):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"]) if "frames" in batch else None
        x = embed(batch["tokens"], params["embed"]).astype(self.dtype)
        x, ys = self._decode_stack(params, x, enc, "prefill", None)
        b, s, _ = x.shape
        if "lengths" in batch:  # bucketed right-padded prompts (see DecoderLM)
            last = batch["lengths"].astype(jnp.int32) - 1
            xl = x[jnp.arange(b), last][:, None]
        else:
            xl = x[:, -1:]
        logits = unembed(xl.astype(jnp.float32), params["embed"],
                         cfg.vocab_size)[:, 0]

        def pad_full(kv):
            if s >= capacity:
                return kv[:, :, s - capacity:]
            pad = [(0, 0)] * kv.ndim
            pad[2] = (0, capacity - s)
            return jnp.pad(kv, pad)

        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        pos = (pos[:, s - capacity:] if s >= capacity else
               jnp.pad(pos, ((0, 0), (0, capacity - s)), constant_values=-1))
        se = enc.shape[1]
        cache = {
            "self": jax.tree.map(pad_full, ys["self"]),
            "cross": ys["cross"],
            "cross_pos": jnp.broadcast_to(
                jnp.arange(se, dtype=jnp.int32)[None], (b, se)),
            "pos": pos,
            "index": jnp.full((b,), min(s, capacity) % max(capacity, 1),
                              jnp.int32),
        }
        return logits, cache

    def cache_specs(self, batch: int, capacity: int):
        cfg = self.cfg
        k, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        dl, se = cfg.num_layers, cfg.encoder_seq
        kv = lambda t: {
            "k": jax.ShapeDtypeStruct((dl, batch, t, k, hd), self.dtype),
            "v": jax.ShapeDtypeStruct((dl, batch, t, k, hd), self.dtype)}
        return {
            "self": kv(capacity),
            "cross": kv(se),
            "cross_pos": jax.ShapeDtypeStruct((batch, se), jnp.int32),
            "pos": jax.ShapeDtypeStruct((batch, capacity), jnp.int32),
            "index": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }

    def cache_axes(self, batch: int, capacity: int):
        kvax = lambda: {"k": ("layers", "batch", "seq", "kv_heads", None),
                        "v": ("layers", "batch", "seq", "kv_heads", None)}
        return {"self": kvax(), "cross": kvax(),
                "cross_pos": ("batch", "seq"), "pos": ("batch", "seq"),
                "index": ("batch",)}

    def init_cache(self, batch: int, capacity: int):
        structs = self.cache_specs(batch, capacity)
        cache = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), structs)
        cache["pos"] = jnp.full((batch, capacity), -1, jnp.int32)
        cache["cross_pos"] = jnp.broadcast_to(
            jnp.arange(cache["cross_pos"].shape[1], dtype=jnp.int32)[None],
            cache["cross_pos"].shape)
        return cache

    def decode_step(self, params, cache, batch, ctx=None):
        # ctx (context-bucket hint) is accepted for API parity with
        # DecoderLM but unused: the cross-attention cache shares the "seq"
        # layout at a different width, so slicing is not worth the special-
        # casing here (the serving engine disables buckets for encdec).
        del ctx
        cfg = self.cfg
        new_cache = dict(cache)
        idx = cache["index"]  # (B,)
        bi = jnp.arange(idx.shape[0])
        new_cache["pos"] = cache["pos"].at[bi, idx].set(
            batch["positions"].astype(jnp.int32))
        cap = cache["pos"].shape[1]
        new_cache["index"] = (idx + 1) % cap
        run_cache = dict(cache)
        run_cache["pos"] = new_cache["pos"]
        run_cache["positions_now"] = batch["positions"][:, None]
        x = embed(batch["tokens"], params["embed"]).astype(self.dtype)
        x, ys = self._decode_stack(params, x, None, "decode", run_cache)
        new_cache["self"] = ys["self"]
        logits = unembed(x.astype(jnp.float32), params["embed"],
                         cfg.vocab_size)[:, 0]
        return logits, new_cache

    def forward(self, params, batch, mode="train", cache=None):
        """Uniform-API hook (hidden states of the decoder)."""
        enc = self.encode(params, batch["frames"])
        x = embed(batch["tokens"], params["embed"]).astype(self.dtype)
        x, _ = self._decode_stack(params, x, enc, "train", None)
        return x, jnp.zeros((), jnp.float32), {}, 0
