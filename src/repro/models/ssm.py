"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked semi-separable computation: quadratic attention-like term within
chunks + linear recurrence across chunks. Decode is an O(1) state update.

TP sharding: heads / d_inner are sharded over the model axis (B/C projections
are small, replicated); out_proj is row-parallel (XLA inserts the reduce).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import rms_norm
from repro.models.spec import ParamSpec


def ssd_specs(cfg: ModelConfig, prefix_axes=()) -> dict:
    ps = tuple(n for n, _ in prefix_axes)
    pa = tuple(a for _, a in prefix_axes)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv
    conv_ch = di + 2 * n  # conv runs over [x, B, C] channels
    return {
        "ln": ParamSpec(ps + (d,), pa + ("embed",), "zeros"),
        "wz": ParamSpec(ps + (d, di), pa + ("embed", "heads"), "scaled"),
        "wx": ParamSpec(ps + (d, di), pa + ("embed", "heads"), "scaled"),
        "wb": ParamSpec(ps + (d, n), pa + ("embed", None), "scaled"),
        "wc": ParamSpec(ps + (d, n), pa + ("embed", None), "scaled"),
        "wdt": ParamSpec(ps + (d, h), pa + ("embed", "heads"), "scaled"),
        "conv_w": ParamSpec(ps + (w, conv_ch), pa + (None, "heads"), "scaled"),
        "conv_b": ParamSpec(ps + (conv_ch,), pa + ("heads",), "zeros"),
        "a_log": ParamSpec(ps + (h,), pa + ("heads",), "ones"),
        "d_skip": ParamSpec(ps + (h,), pa + ("heads",), "ones"),
        "dt_bias": ParamSpec(ps + (h,), pa + ("heads",), "zeros"),
        "gn": ParamSpec(ps + (di,), pa + ("heads",), "zeros"),  # gated RMSNorm
        "wo": ParamSpec(ps + (di, d), pa + ("heads", "embed"), "scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B,S,C); w: (W,C); state: (B,W-1,C) history.

    Returns (y (B,S,C), new_state (B,W-1,C)).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    y = y + b[None, None, :]
    new_state = xp[:, xp.shape[1] - (width - 1):, :]
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
                 cmat: jax.Array, chunk: int, h0: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: (B,S,H,P) inputs per head; dt: (B,S,H) softplus'd step; a: (H,)
    negative decay rate; bmat/cmat: (B,S,N). Returns (y (B,S,H,P),
    h_final (B,H,P,N)).
    """
    b_, s, h, p = xh.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:  # ragged tail: dt=0 padding is exact (no state contribution)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        s_orig, s = s, s + pad
    nc = s // q
    f32 = jnp.float32

    xc = xh.reshape(b_, nc, q, h, p).astype(f32)
    dtc = dt.reshape(b_, nc, q, h).astype(f32)
    bc = bmat.reshape(b_, nc, q, n).astype(f32)
    cc = cmat.reshape(b_, nc, q, n).astype(f32)

    da = dtc * a[None, None, None, :]  # (B,nc,Q,H) negative
    cum = jnp.cumsum(da, axis=2)  # within-chunk cumulative decay exponent
    total = cum[:, :, -1:, :]  # (B,nc,1,H)

    # ---- intra-chunk (quadratic within chunk) ----
    # L[i,j] = exp(cum_i - cum_j) for i >= j. Mask INSIDE the exp: the
    # upper triangle has positive exponents that overflow to inf, and the
    # where-vjp would turn 0*inf into NaN gradients otherwise.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    li = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    decay = jnp.exp(jnp.where(li, diff, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B,nc,Q,Q)
    w_ij = cb[..., None] * decay * dtc[:, :, None, :, :]  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xc)

    # ---- chunk states ----
    # state_c = sum_j exp(total - cum_j) * dt_j * B_j (x) x_j
    sdecay = jnp.exp(total - cum)  # (B,nc,Q,H)
    sx = xc * (dtc * sdecay)[..., None]  # (B,nc,Q,H,P)
    states = jnp.einsum("bcqn,bcqhp->bchpn", bc, sx)  # (B,nc,H,P,N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(total[:, :, 0, :])  # (B,nc,H)

    def step(hprev, inp):
        dec, st = inp  # (B,H), (B,H,P,N)
        hnew = hprev * dec[:, :, None, None] + st
        return hnew, hprev  # emit state ENTERING the chunk

    if h0 is None:
        h0 = jnp.zeros((b_, h, p, n), f32)
    hN, h_in = jax.lax.scan(
        step, h0,
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,nc,H,P,N) state entering each chunk

    # ---- inter-chunk output: y_inter[i] = exp(cum_i) * C_i . h_in ----
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cc, h_in) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b_, s, h, p)
    if pad:
        y = y[:, :s_orig]
    return y, hN


def ssd_forward(params: dict, x: jax.Array, cfg: ModelConfig, *,
                conv_state: Optional[jax.Array] = None,
                h_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, dict]:
    """Full-sequence (train/prefill) Mamba-2 block. x: (B,S,D).

    Returns (y (B,S,D), cache {"conv": (B,W-1,C), "h": (B,H,P,N)}).
    """
    b, s, d = x.shape
    h_heads, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    res = x
    xn = rms_norm(x, params["ln"], cfg.norm_eps)

    z = jnp.einsum("bsd,de->bse", xn, params["wz"])
    xi = jnp.einsum("bsd,de->bse", xn, params["wx"])
    bm = jnp.einsum("bsd,dn->bsn", xn, params["wb"])
    cm = jnp.einsum("bsd,dn->bsn", xn, params["wc"])
    dt = jnp.einsum("bsd,dh->bsh", xn, params["wdt"])

    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    di = cfg.d_inner
    xi, bm, cm = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))

    xh = xi.reshape(b, s, h_heads, p)
    y, h_new = _ssd_chunked(xh, dt, a, bm, cm, cfg.ssm_chunk, h_state)
    y = y + xh.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["gn"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"])
    return res + out, {"conv": new_conv, "h": h_new}


def ssd_decode(params: dict, x: jax.Array, cfg: ModelConfig, cache: dict
               ) -> Tuple[jax.Array, dict]:
    """Single-token decode. x: (B,1,D); cache {"conv", "h"}."""
    b, _, d = x.shape
    h_heads, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    res = x
    xn = rms_norm(x, params["ln"], cfg.norm_eps)

    z = jnp.einsum("bsd,de->bse", xn, params["wz"])
    xi = jnp.einsum("bsd,de->bse", xn, params["wx"])
    bm = jnp.einsum("bsd,dn->bsn", xn, params["wb"])
    cm = jnp.einsum("bsd,dn->bsn", xn, params["wc"])
    dt = jnp.einsum("bsd,dh->bsh", xn, params["wdt"])

    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 cache["conv"])
    di = cfg.d_inner
    xi, bm, cm = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a[None, :])  # (B,H)

    xh = xi[:, 0].reshape(b, h_heads, p).astype(jnp.float32)
    hprev = cache["h"]
    # h = exp(dt*a) h + dt * B (x) x
    hnew = (hprev * da[:, :, None, None]
            + jnp.einsum("bn,bhp->bhpn", bm[:, 0].astype(jnp.float32),
                         xh * dt[..., None]))
    y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), hnew)
    y = y + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), params["gn"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["wo"])
    return res + out, {"conv": new_conv, "h": hnew}


def ssd_cache_shape(cfg: ModelConfig, batch: int) -> dict:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": (batch, cfg.ssm_conv - 1, conv_ch),
        "h": (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
    }
