"""Analytic parameter counts (exact: derived from the same spec trees that
drive init/sharding). Used by the cost model and MODEL_FLOPS = 6·N·D."""
from __future__ import annotations

from repro.config import ModelConfig
from repro.models.spec import count_tree, is_spec

import jax


def _specs(cfg: ModelConfig):
    from repro.models import build_model

    return build_model(cfg).specs()


def count_params(cfg: ModelConfig) -> int:
    return count_tree(_specs(cfg))


def count_active_params(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    if not cfg.num_experts:
        return count_params(cfg)
    specs = _specs(cfg)
    total = count_tree(specs)
    moe = specs["blocks"]["moe"]
    expert_tree = {"wi": moe["wi"], "wo": moe["wo"]}
    if "wg" in moe:
        expert_tree["wg"] = moe["wg"]
    per_layer_expert = count_tree(expert_tree)
    n_layers = moe["wi"].shape[0]
    per_expert = per_layer_expert // n_layers // cfg.num_experts
    inactive = (cfg.num_experts - cfg.top_k) * per_expert * n_layers
    return total - inactive


def embedding_params(cfg: ModelConfig) -> int:
    specs = _specs(cfg)
    n = count_tree({"embed": specs["embed"]})
    if "unembed" in specs:
        n += count_tree({"unembed": specs["unembed"]})
    return n
