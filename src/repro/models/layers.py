"""Shared building blocks: norms, RoPE, activations, embeddings, vocab padding."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a multiple so the unembedding shards evenly over TP."""
    return ((v + multiple - 1) // multiple) * multiple


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def activation_fn(name: str, x: jax.Array, gate: Optional[jax.Array] = None) -> jax.Array:
    """Gated and ungated MLP activations. ``gate`` is the linear branch of GLU."""
    if name == "swiglu":
        return jax.nn.silu(x) * gate
    if name == "geglu":
        return jax.nn.gelu(x) * gate
    if name == "squared_relu":  # Primer / Nemotron-4
        r = jax.nn.relu(x)
        return r * r
    if name == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(f"unknown activation {name!r}")


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """cos/sin tables for the given positions. positions: (...,) int32.

    Returns (cos, sin) with shape positions.shape + (head_dim // 2,), float32.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dtype = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """tokens: (B, S) int32; table: (V_pad, D)."""
    return jnp.take(table, tokens, axis=0)


def unembed(x: jax.Array, table: jax.Array, true_vocab: int) -> jax.Array:
    """Project to logits; mask the padded vocab tail with -inf.

    x: (B, S, D); table: (V_pad, D) -> logits (B, S, V_pad).
    """
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    v_pad = table.shape[0]
    if v_pad != true_vocab:
        mask = jnp.arange(v_pad) < true_vocab
        logits = jnp.where(mask[None, None, :], logits, -1e30)
    return logits


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token-level CE. logits (B,S,V), labels (B,S) int32, mask (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
