from repro.distributed.sharding import (  # noqa: F401
    DEFAULT_RULES,
    logical_constraint,
    param_shardings,
    spec_to_pspec,
)
