"""Logical-axis -> mesh-axis resolution.

Parameters and activations carry *logical* axis names (see
``repro.models.spec.ParamSpec``); this module resolves them to
``PartitionSpec``s for a concrete mesh, with divisibility fallbacks (an axis
that doesn't divide evenly is replicated rather than erroring) and optional
ZeRO-3 (FSDP) sharding of the remaining large dims over the data axis.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import spec as pspec_mod
from repro.models.spec import ParamSpec

# logical axis -> mesh axis (tuples tried jointly; filtered by mesh axes)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "seq_sp": ("model",),  # sequence-parallel residuals (batch owns data)
    "embed": (),
    "heads": ("model",),
    "heads_in": (),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "experts": ("model",),
    "experts_r": (),
    "expert_ffn": (),
    "vocab": ("model",),
    "layers": (),
    "repeats": (),
    "pattern": (),
    "state": (),
    "frontend": (),
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve_axis(logical: Optional[str], dim: int, mesh: Mesh,
                  rules: Dict[str, Tuple[str, ...]]):
    """One logical axis -> mesh axis tuple (or None), divisibility-checked."""
    if logical is None:
        return None
    want = rules.get(logical, ())
    sizes = _mesh_axis_sizes(mesh)
    chosen = tuple(a for a in want if a in sizes)
    if not chosen:
        return None
    total = int(np.prod([sizes[a] for a in chosen]))
    if dim % total != 0:
        # try dropping axes from the left (pod first) until it divides
        while chosen and dim % int(np.prod([sizes[a] for a in chosen])) != 0:
            chosen = chosen[1:]
        if not chosen:
            return None
    return chosen if len(chosen) > 1 else chosen[0]


def spec_to_pspec(ps: ParamSpec, mesh: Mesh,
                  rules: Dict[str, Tuple[str, ...]] = DEFAULT_RULES,
                  fsdp_axes: Sequence[str] = ()) -> PartitionSpec:
    """Resolve one ParamSpec to a PartitionSpec (optionally FSDP over data)."""
    entries = [
        _resolve_axis(ax, dim, mesh, rules)
        for ax, dim in zip(ps.axes, ps.shape)
    ]
    if fsdp_axes:
        sizes = _mesh_axis_sizes(mesh)
        used = set()
        for e in entries:
            if e is None:
                continue
            used.update(e if isinstance(e, tuple) else (e,))
        fs = tuple(a for a in fsdp_axes if a in sizes and a not in used)
        total = int(np.prod([sizes[a] for a in fs])) if fs else 1
        if fs and total > 1:
            # shard the LARGEST still-unsharded dim over the fsdp axes
            cands = [(dim, i) for i, (dim, e) in enumerate(zip(ps.shape, entries))
                     if e is None and dim % total == 0]
            if cands:
                _, idx = max(cands)
                entries[idx] = fs if len(fs) > 1 else fs[0]
    return PartitionSpec(*entries)


def param_shardings(specs, mesh: Mesh,
                    rules: Dict[str, Tuple[str, ...]] = DEFAULT_RULES,
                    fsdp_axes: Sequence[str] = ()):
    """ParamSpec tree -> NamedSharding tree."""
    return pspec_mod.tree_map_specs(
        lambda s: NamedSharding(mesh, spec_to_pspec(s, mesh, rules, fsdp_axes)),
        specs)


def logical_pspec(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
                  mesh: Mesh,
                  rules: Dict[str, Tuple[str, ...]] = DEFAULT_RULES
                  ) -> PartitionSpec:
    return PartitionSpec(*[
        _resolve_axis(ax, dim, mesh, rules) for ax, dim in zip(axes, shape)])


def logical_constraint(x: jax.Array, axes: Tuple[Optional[str], ...],
                       mesh: Optional[Mesh],
                       rules: Dict[str, Tuple[str, ...]] = DEFAULT_RULES
                       ) -> jax.Array:
    """Apply with_sharding_constraint by logical names (no-op without mesh)."""
    if mesh is None:
        return x
    ps = logical_pspec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))


def batch_shardings(tree_axes, tree_shapes, mesh: Mesh,
                    rules=DEFAULT_RULES):
    """Input-batch sharding tree from parallel (axes, shapes) trees."""
    return jax.tree.map(
        lambda axes, shp: NamedSharding(mesh, logical_pspec(axes, shp, mesh, rules)),
        tree_axes, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
