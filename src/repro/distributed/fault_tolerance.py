"""Fault-tolerance coordination for multi-pod training/serving.

Single-controller pattern: a HeartbeatRegistry tracks liveness of worker
groups (pods / hosts); on a missed deadline the RecoveryCoordinator decides
between (a) restart-in-place from the latest checkpoint, (b) elastic
downsize (rebuild the mesh without the dead pod and re-shard via
``repro.checkpoint.elastic``), or (c) hot-spare swap. On one host this is
exercised with simulated clocks in tests; the decision logic is exactly what
a 1000-node deployment runs — detection is transport-level either way.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class WorkerHealth:
    name: str
    last_beat: float
    failures: int = 0
    alive: bool = True


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.workers: Dict[str, WorkerHealth] = {}

    def register(self, name: str) -> None:
        self.workers[name] = WorkerHealth(name, self.clock())

    def beat(self, name: str) -> None:
        w = self.workers[name]
        w.last_beat = self.clock()
        w.alive = True

    def check(self) -> List[str]:
        """Returns newly-dead worker names."""
        now = self.clock()
        dead = []
        for w in self.workers.values():
            if w.alive and now - w.last_beat > self.timeout:
                w.alive = False
                w.failures += 1
                dead.append(w.name)
        return dead

    def alive_workers(self) -> List[str]:
        return [w.name for w in self.workers.values() if w.alive]


@dataclass
class RecoveryEvent:
    t: float
    worker: str
    action: str  # restart | elastic_downsize | spare_swap
    detail: str = ""


class RecoveryCoordinator:
    """Policy: use a hot spare if available; otherwise downsize the mesh if
    the job tolerates it (>= min_workers); otherwise restart-in-place and
    wait for the scheduler to reprovision."""

    def __init__(self, registry: HeartbeatRegistry, min_workers: int = 1,
                 spares: Optional[List[str]] = None):
        self.reg = registry
        self.min_workers = min_workers
        self.spares = list(spares or [])
        self.log: List[RecoveryEvent] = []

    def tick(self) -> List[RecoveryEvent]:
        events = []
        for dead in self.reg.check():
            if self.spares:
                spare = self.spares.pop(0)
                self.reg.register(spare)
                ev = RecoveryEvent(self.reg.clock(), dead, "spare_swap",
                                   f"replaced by {spare}")
            elif len(self.reg.alive_workers()) >= self.min_workers:
                ev = RecoveryEvent(self.reg.clock(), dead, "elastic_downsize",
                                   f"{len(self.reg.alive_workers())} left")
            else:
                ev = RecoveryEvent(self.reg.clock(), dead, "restart",
                                   "below min_workers; full restart")
            self.log.append(ev)
            events.append(ev)
        return events
