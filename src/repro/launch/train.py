"""Training launcher.

Local smoke:   PYTHONPATH=src python -m repro.launch.train --model tiny-dense --steps 50
Reduced arch:  PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b --reduced
Mesh runs use the same code path with ``--mesh data,model`` on real hardware;
on this CPU container meshes beyond 1 device exist only via the dry-run.
"""
from __future__ import annotations

import argparse

from repro.config import OptimizerConfig, ShardingConfig, TrainConfig
from repro.configs import reduced_config
from repro.models import build_model
from repro.training import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="tiny-dense")
    ap.add_argument("--arch", default=None,
                    help="assigned arch id; combined with --reduced")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = TrainConfig(
        model=args.model, batch_size=args.batch, seq_len=args.seq,
        steps=args.steps, checkpoint_dir=args.ckpt_dir,
        checkpoint_every=args.ckpt_every,
        optimizer=OptimizerConfig(lr=args.lr, total_steps=args.steps),
        sharding=ShardingConfig(gradient_accum=args.accum),
    )
    model = None
    if args.arch:
        mcfg = reduced_config(args.arch) if args.reduced else None
        if mcfg is None:
            from repro.configs import get_config
            mcfg = get_config(args.arch)
        model = build_model(mcfg.replace(dtype="float32"),
                            sharding=cfg.sharding, param_dtype="float32")
    trainer = Trainer(cfg, model=model)
    trainer.initialize(resume=not args.no_resume)
    trainer.train()


if __name__ == "__main__":
    main()
