"""Trip-count-aware cost analysis over optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — under
scan-over-layers that understates FLOPs/bytes by ~num_layers. This module
re-derives the three roofline inputs from the HLO text itself:

  · FLOPs: every ``dot`` — 2 * prod(output dims) * prod(lhs contracting
    dims) — multiplied by the effective trip count of its computation
    (``known_trip_count`` from the while op's backend_config, nesting-aware);
  · HBM bytes: fusion-boundary traffic — each top-level instruction of a
    REAL computation (entry / while bodies / conditional branches) reads its
    operands and writes its outputs once per trip. Interiors of fusions
    (``%fused_computation*``, ``%wrapped_*``) never touch HBM and are skipped;
  · collective bytes: payload sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, trip-multiplied.

All numbers are PER-DEVICE (the module is the per-partition SPMD program).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8,
               "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops with no fusion-boundary HBM traffic of their own
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "while",
             "conditional", "reshape", "call", "custom-call", "copy-start",
             "copy-done", "send", "recv", "send-done", "recv-done",
             "opt-barrier"}

_TYPE_RE = re.compile(r"\b([a-z]+\d+[a-z0-9]*|pred)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(?:\([^()]*\)|[a-z0-9_\[\]{},\s]+?)\s*"
                    r"([a-z][a-z0-9\-]*)\(")
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)')


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _bytes_of(types: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in types:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def split_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation name -> body lines. Headers sit at column 0, end with '{'
    and contain '->' (signatures may contain nested parens — match by name)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if (line and not line[0].isspace() and line.endswith("{")
                    and "->" in line):
                m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)", line)
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _parse_instrs(lines: List[str]):
    """[(name, opname, out_types, operand_names, line)], symbol table."""
    instrs = []
    table: Dict[str, List[Tuple[str, List[int]]]] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # strip metadata/backend_config tails for operand parsing accuracy
        head, _, _ = rhs.partition(" metadata=")
        # output types: everything before the op call
        call = re.search(r"\b([a-z][a-z0-9\-]*)\(", head)
        opname = call.group(1) if call else ""
        out_part = head[: call.start()] if call else head
        out_types = [( t.group(1), _dims(t.group(2)))
                     for t in _TYPE_RE.finditer(out_part)]
        operand_part = head[call.end():] if call else ""
        # operands: %refs before the first attribute (fusion calls=%..., etc.)
        operand_part = operand_part.split("calls=")[0]
        operand_part = operand_part.split("condition=")[0]
        operand_part = operand_part.split("to_apply=")[0]
        operands = _OPERAND_RE.findall(operand_part.split("),")[0])
        table[name] = out_types
        instrs.append((name, opname, out_types, operands, line))
    return instrs, table


def analyze_hlo(hlo: str, default_trip: int = 1) -> Dict[str, Any]:
    comps = split_computations(hlo)
    parsed = {name: _parse_instrs(lines) for name, lines in comps.items()}

    # ---- trip counts (nesting-aware fixpoint) ----
    body_trip: Dict[str, int] = {}
    body_parent: Dict[str, str] = {}
    for cname, (instrs, _) in parsed.items():
        for _, op, _, _, line in instrs:
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", line)
                if not bm:
                    continue
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else default_trip
                body = bm.group(1)
                body_trip[body] = max(body_trip.get(body, 1), trip)
                body_parent[body] = cname
                cm = re.search(r"condition=%?([\w.\-]+)", line)
                if cm:
                    body_trip.setdefault(cm.group(1), trip)
                    body_parent.setdefault(cm.group(1), cname)

    def eff_trip(comp: str, depth: int = 0) -> int:
        if depth > 16:
            return 1
        t = body_trip.get(comp, 1)
        parent = body_parent.get(comp)
        return t * (eff_trip(parent, depth + 1) if parent else 1)

    def _is_fused(name: str) -> bool:
        return name.startswith(("fused", "wrapped_"))

    # ---- fusion call counts: fused computation -> Σ eff_trip(call sites) ----
    fusion_calls: Dict[str, float] = {}
    for cname, (instrs, _) in parsed.items():
        if _is_fused(cname):
            continue
        mult = eff_trip(cname)
        for _, op, _, _, line in instrs:
            m = re.search(r"calls=%?([\w.\-]+)", line)
            if m:
                fusion_calls[m.group(1)] = fusion_calls.get(m.group(1), 0.0) + mult

    def _dot_flops(line, operands, out_types, table) -> float:
        k = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
        cdims = _dims(m.group(1)) if m else []
        lhs = table.get(operands[0], []) if operands else []
        lhs_dims = lhs[0][1] if lhs else []
        for ci in cdims:
            if ci < len(lhs_dims):
                k *= lhs_dims[ci]
        n_out = 1
        for _, dims in out_types:
            for d in dims:
                n_out *= d
        return 2.0 * n_out * max(k, 1)

    # ---- walk real computations ----
    flops = 0.0
    hbm_bytes = 0.0
    coll_bytes = 0.0
    per_coll = {c: 0.0 for c in COLLECTIVES}
    dots = 0

    # dots hidden inside fusions: flops attributed via the call-site trips
    for cname, (instrs, table) in parsed.items():
        if not _is_fused(cname):
            continue
        mult = fusion_calls.get(cname, 0.0)
        if mult <= 0:
            continue
        for name, op, out_types, operands, line in instrs:
            if op == "dot":
                flops += _dot_flops(line, operands, out_types, table) * mult
                dots += 1

    for cname, (instrs, table) in parsed.items():
        if _is_fused(cname):
            continue  # fusion interiors: traffic counted at the call site
        mult = eff_trip(cname)
        for name, op, out_types, operands, line in instrs:
            if not op:
                continue
            if op == "dot":
                flops += _dot_flops(line, operands, out_types, table) * mult
                dots += 1
            base_coll = next((c for c in COLLECTIVES if op.startswith(c)), None)
            if base_coll is not None:
                if op.endswith("-done"):
                    continue
                sz = _bytes_of(out_types)
                per_coll[base_coll] += sz * mult
                coll_bytes += sz * mult
                hbm_bytes += sz * mult
                continue
            if op in _FREE_OPS:
                continue
            if op in ("slice", "dynamic-slice", "gather"):
                # reads only the sliced region (≈ output), not the operand
                nbytes = 2 * _bytes_of(out_types)
            elif op in ("dynamic-update-slice", "scatter", "scatter-add"):
                # reads + writes only the updated region (≈ update operand)
                upd = (_bytes_of(table.get(operands[1], []))
                       if len(operands) > 1 else _bytes_of(out_types))
                nbytes = 2 * upd
            else:
                nbytes = _bytes_of(out_types)
                for o in operands:
                    nbytes += _bytes_of(table.get(o, []))
            hbm_bytes += nbytes * mult

    return {"flops": flops, "hbm_bytes": hbm_bytes,
            "collective_bytes": coll_bytes, "per_collective": per_coll,
            "num_dots": dots,
            "trip_counts": {k: v for k, v in body_trip.items() if v > 1}}
