import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: pjit with the
production in/out shardings must partition, compile, and report memory +
cost analysis for the 16x16 single-pod mesh AND the (2,16,16) multi-pod
mesh. Collective bytes are parsed from the partitioned HLO (while-loop
bodies multiplied by their parsed trip counts) for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.config import (ROOFLINE, OptimizerConfig, ShardingConfig, SHAPES)
from repro.configs import ASSIGNED_ARCHS, get_config, iter_dryrun_cells
from repro.distributed.sharding import (DEFAULT_RULES, logical_pspec,
                                        param_shardings)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.optimizer import OptState
from repro.training.train_loop import make_train_step


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------


def _batch_shardings(in_specs, in_axes, mesh, rules):
    return {
        k: NamedSharding(mesh, logical_pspec(in_axes[k], v.shape, mesh, rules))
        for k, v in in_specs.items()
    }


def _axes_shardings(struct_tree, axes_tree, mesh, rules):
    return jax.tree.map(
        lambda sd, ax: NamedSharding(
            mesh, logical_pspec(ax, sd.shape, mesh, rules)),
        struct_tree, axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def build_cell(arch: str, shape_name: str, mesh, *,
               sharding_cfg: Optional[ShardingConfig] = None,
               rules: Optional[dict] = None,
               cache_rules: Optional[dict] = None,
               moe_2d: bool = False):
    """Returns (jitted_fn, example_args, meta) ready to .lower(*args)."""
    rules = rules or DEFAULT_RULES
    cache_rules = cache_rules or rules
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    moe_impl = "auto"
    if moe_2d and cfg.num_experts and shape.kind == "decode":
        # weight-stationary 2D expert parallelism (decode): experts over
        # data, expert-FFN over model -> weights never move at decode time
        rules = dict(rules)
        rules.update({"experts": ("data",), "expert_ffn": ("model",)})
        cache_rules = dict(cache_rules) if cache_rules else rules
        moe_impl = "decode2d"
    big = cfg.param_count() > 60e9
    if sharding_cfg is None:
        # baseline defaults:
        # · full remat (recompute-everything) — lowest activation memory;
        # · gradient accumulation so the per-layer saved residual stack
        #   (L x B_local/accum x S x D, the irreducible remat footprint)
        #   stays under ~2 GB/device;
        # · sequence-parallel residuals for wide models (saved activations
        #   additionally sharded over the model axis).
        # The §Perf hillclimb trades these against compute/collective terms.
        accum = 1
        if shape.kind == "train":
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            dp = max(1, sizes.get("data", 1) * sizes.get("pod", 1))
            tp = max(1, sizes.get("model", 1))
            b_local = max(1, shape.global_batch // dp)
            seq_shard = tp if cfg.d_model % tp == 0 and cfg.d_model >= 4096 else 1
            saved = (cfg.num_layers * b_local * shape.seq_len
                     * cfg.d_model * 2 / seq_shard)
            while accum < b_local and saved / accum > 2e9:
                accum *= 2
        sharding_cfg = ShardingConfig(
            zero_stage=3 if big else 1, remat_policy="full",
            gradient_accum=accum,
            sequence_parallel=cfg.d_model >= 4096)
    model = build_model(cfg, mesh=mesh, sharding=sharding_cfg,
                        moe_impl=moe_impl)

    pspecs = model.specs() if hasattr(model, "specs") else None
    fsdp = ("data", "pod") if sharding_cfg.zero_stage >= 3 else ()
    if moe_impl == "decode2d":
        fsdp = ()  # weights are statically 2D-sharded; never re-gathered
    p_shard = param_shardings(pspecs, mesh, rules, fsdp_axes=fsdp)
    p_struct = model.param_shapes()

    in_specs, in_axes = model.input_specs(shape)
    b_shard = _batch_shardings(in_specs, in_axes, mesh, rules)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig(state_dtype="bfloat16" if big else "float32")
        st_dt = jnp.dtype(opt_cfg.state_dtype)
        mv_struct = jax.tree.map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, st_dt), p_struct)
        opt_struct = OptState(jax.ShapeDtypeStruct((), jnp.int32),
                              mv_struct, mv_struct)
        # ZeRO >= 1: optimizer states always FSDP-sharded over data(+pod)
        mv_shard = param_shardings(pspecs, mesh, rules,
                                   fsdp_axes=("data", "pod"))
        opt_shard = OptState(NamedSharding(mesh, PartitionSpec()),
                             mv_shard, mv_shard)
        step_fn = make_train_step(model, opt_cfg,
                                  sharding_cfg.gradient_accum)
        fn = jax.jit(step_fn,
                     in_shardings=(p_shard, opt_shard, b_shard),
                     out_shardings=(p_shard, opt_shard, None),
                     donate_argnums=(0, 1))
        args = (p_struct, opt_struct, in_specs)
    elif shape.kind == "prefill":
        cap = shape.seq_len
        fn = jax.jit(lambda p, b: model.prefill(p, b, cap),
                     in_shardings=(p_shard, b_shard))
        args = (p_struct, in_specs)
    else:  # decode
        cap = shape.seq_len
        c_struct = model.cache_specs(shape.global_batch, cap)
        c_axes = model.cache_axes(shape.global_batch, cap)
        c_shard = _axes_shardings(c_struct, c_axes, mesh, cache_rules)
        fn = jax.jit(model.decode_step,
                     in_shardings=(p_shard, c_shard, b_shard),
                     out_shardings=(None, c_shard),
                     donate_argnums=(1,))
        args = (p_struct, c_struct, in_specs)

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "zero_stage": sharding_cfg.zero_stage}
    return fn, args, meta, cfg


# ---------------------------------------------------------------------------
# the run
# ---------------------------------------------------------------------------


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N_active·D forward (decode: per step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             sharding_cfg: Optional[ShardingConfig] = None,
             rules=None, cache_rules=None, tag: str = "",
             moe_2d: bool = False) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.perf_counter()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi_pod else "single",
                           "devices": int(n_dev), "tag": tag}
    try:
        fn, args, meta, cfg = build_cell(arch, shape_name, mesh,
                                         sharding_cfg=sharding_cfg,
                                         rules=rules, cache_rules=cache_rules,
                                         moe_2d=moe_2d)
        rec.update(meta)
        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        shape = SHAPES[shape_name]
        hlo = analyze_hlo(compiled.as_text(), default_trip=cfg.num_layers)

        mem_rec = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                mem_rec[k] = int(v)

        mf = model_flops(cfg, shape)
        # analyze_hlo numbers are per-device (per-partition SPMD module)
        flops = hlo["flops"]
        bytes_acc = hlo["hbm_bytes"]
        coll_bytes = hlo["collective_bytes"]
        hlo_flops_total = flops * n_dev
        compute_t = flops / ROOFLINE.peak_flops
        memory_t = bytes_acc / ROOFLINE.hbm_bw
        coll_t = coll_bytes / ROOFLINE.ici_bw  # per-device bytes over 1 link
        dominant = max((("compute", compute_t), ("memory", memory_t),
                        ("collective", coll_t)), key=lambda kv: kv[1])[0]
        rec.update({
            "ok": True,
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "memory": mem_rec,
            "per_device_flops": flops,
            "per_device_bytes": bytes_acc,
            "collective_bytes_per_device": coll_bytes,
            "collective_per_op": hlo["per_collective"],
            "xla_cost_analysis_flops": float(cost.get("flops", 0.0)) if cost else 0.0,
            "model_flops": mf,
            "hlo_flops_total": hlo_flops_total,
            "useful_flops_ratio": mf / hlo_flops_total if hlo_flops_total else 0,
            "roofline": {"compute_s": compute_t, "memory_s": memory_t,
                         "collective_s": coll_t, "dominant": dominant},
        })
        args_b = mem_rec.get("argument_size_in_bytes", 0)
        temp_b = mem_rec.get("temp_size_in_bytes", 0)
        rec["fits_hbm"] = bool(args_b + temp_b <= ROOFLINE.hbm_per_chip)
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: OK "
              f"compile={rec['compile_s']}s flops/dev={flops:.3g} "
              f"coll={coll_bytes:.3g}B dom={dominant} "
              f"useful={rec['useful_flops_ratio']:.2f} "
              f"args+temp={(args_b + temp_b) / 1e9:.2f}GB")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: FAIL {rec['error']}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = os.path.join(
            out_dir, f"dryrun_{arch}_{shape_name}_{rec['mesh']}{suffix}.json")
        with open(fname, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results")
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--seq-par", type=int, default=None, choices=[0, 1])
    ap.add_argument("--cache-seq-shard", action="store_true",
                    help="shard decode KV cache sequence dim over model axis")
    ap.add_argument("--moe-2d", action="store_true",
                    help="weight-stationary 2D expert parallelism for decode")
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    shard_cfg = None
    if any(v is not None for v in (args.zero, args.remat, args.accum,
                                   args.seq_par)):
        shard_cfg = ShardingConfig(
            zero_stage=args.zero if args.zero is not None else 3,
            remat_policy=args.remat or "full",
            gradient_accum=args.accum or 1,
            sequence_parallel=bool(args.seq_par)
            if args.seq_par is not None else True)
    cache_rules = None
    if args.cache_seq_shard:
        cache_rules = dict(DEFAULT_RULES)
        cache_rules["seq"] = ("model",)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for arch, shape_name, skip in iter_dryrun_cells():
            cells.append((arch, shape_name, skip))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape, None))

    results = []
    for arch, shape_name, skip in cells:
        for mp in meshes:
            if skip:
                results.append({"arch": arch, "shape": shape_name,
                                "mesh": "multi" if mp else "single",
                                "ok": None, "skipped": skip})
                print(f"[dryrun] {arch} {shape_name}: SKIP ({skip[:60]}…)")
                continue
            if args.skip_existing:
                fname = os.path.join(
                    args.out, f"dryrun_{arch}_{shape_name}_"
                    f"{'multi' if mp else 'single'}.json")
                if os.path.exists(fname):
                    with open(fname) as f:
                        prev = json.load(f)
                    if prev.get("ok"):
                        results.append(prev)
                        continue
            results.append(run_cell(arch, shape_name, mp, args.out,
                                    sharding_cfg=shard_cfg,
                                    cache_rules=cache_rules, tag=args.tag,
                                    moe_2d=args.moe_2d))
    ok = sum(1 for r in results if r.get("ok"))
    skipped = sum(1 for r in results if r.get("ok") is None)
    fail = sum(1 for r in results if r.get("ok") is False)
    print(f"[dryrun] done: {ok} ok, {skipped} skipped-by-design, {fail} failed")
    if args.out:
        with open(os.path.join(args.out, "dryrun_summary.json"), "w") as f:
            json.dump(results, f, indent=1, default=str)


if __name__ == "__main__":
    main()
