"""Two-tier serving launcher: MoA-Off scheduler + live engines on reduced
models (the paper's edge/cloud pair), driven by a synthetic request stream.

PYTHONPATH=src python -m repro.launch.serve --requests 16 --bandwidth 300e6
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.config import ServingConfig
from repro.configs import reduced_config
from repro.data.synthetic import make_image
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.tiers import EdgeCloudServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--bandwidth", type=float, default=300e6)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    sv = ServingConfig(max_batch=args.max_batch, max_seq=128)
    edge_cfg = reduced_config("qwen2-vl-2b").replace(dtype="float32")
    cloud_cfg = reduced_config("qwen2.5-vl-7b").replace(dtype="float32")
    em = build_model(edge_cfg)
    cm = build_model(cloud_cfg)
    edge = TierEngine(em, em.init(jax.random.PRNGKey(0)), sv)
    cloud = TierEngine(cm, cm.init(jax.random.PRNGKey(1)), sv)
    server = EdgeCloudServer(edge, cloud, bandwidth_bps=args.bandwidth)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        u = rng.beta(1.6, 1.6)
        img = make_image(rng, u, 64, 64)
        text = (f"Request {i}: describe the Scene {i * 3}. "
                + "and then explain why it matters. " * rng.integers(1, 12))
        server.submit(text, image=img, max_new=args.max_new)

    results = server.run()
    n_edge = sum(r.tier == "edge" for r in results)
    lat = np.mean([r.latency_s for r in results])
    print(f"served {len(results)} requests | edge={n_edge} "
          f"cloud={len(results) - n_edge} | mean latency {lat:.3f}s")
    for r in sorted(results, key=lambda r: r.rid)[:10]:
        print(f"  rid={r.rid} tier={r.tier:5s} routes={r.routes} "
              f"lat={r.latency_s:.3f}s")


if __name__ == "__main__":
    main()
