"""Serving launcher: MoA-Off scheduler + live engines on reduced models,
driven by a synthetic request stream.

Default is the paper's two-tier edge/cloud pair; ``--topology`` selects any
registered ``ClusterTopology`` (e.g. ``edge-regional-cloud``) and spins up
one reduced-model engine per tier.

PYTHONPATH=src python -m repro.launch.serve --requests 16 --bandwidth 300e6
PYTHONPATH=src python -m repro.launch.serve --topology edge-regional-cloud
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import TOPOLOGIES, ServingConfig, get_topology
from repro.configs import reduced_config
from repro.data.synthetic import make_image
from repro.models import build_model
from repro.serving.engine import TierEngine
from repro.serving.tiers import ClusterServer


def build_engines(topology, sv: ServingConfig) -> dict:
    engines = {}
    for i, tier in enumerate(topology.tiers):
        cfg = reduced_config(tier.model).replace(dtype="float32")
        model = build_model(cfg)
        engines[tier.name] = TierEngine(
            model, model.init(jax.random.PRNGKey(i)), sv)
    return engines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--bandwidth", type=float, default=None,
                    help="override every remote uplink (bps); default keeps "
                         "the topology's declared links")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fused-steps", type=int, default=8,
                    help="decode steps fused per host call (1 = legacy "
                         "per-token path)")
    ap.add_argument("--decode-impl", default="auto",
                    choices=["auto", "xla", "pallas"],
                    help="decode attention backend (auto = Pallas on TPU)")
    ap.add_argument("--topology", default="edge-cloud",
                    choices=sorted(TOPOLOGIES),
                    help="cluster topology to serve (one engine per tier)")
    args = ap.parse_args()

    sv = ServingConfig(max_batch=args.max_batch, max_seq=128,
                       fused_steps=args.fused_steps,
                       decode_impl=args.decode_impl)
    topo = get_topology(args.topology)
    if args.bandwidth is not None:
        topo = dataclasses.replace(topo, tiers=tuple(
            dataclasses.replace(t, uplink_bps=args.bandwidth)
            if t.is_remote else t for t in topo.tiers))
    print(f"topology {topo.name}: tiers {', '.join(topo.names)}")
    server = ClusterServer(build_engines(topo, sv), topology=topo)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        u = rng.beta(1.6, 1.6)
        img = make_image(rng, u, 64, 64)
        text = (f"Request {i}: describe the Scene {i * 3}. "
                + "and then explain why it matters. " * rng.integers(1, 12))
        server.submit(text, image=img, max_new=args.max_new)

    t0 = time.perf_counter()
    results = server.run()
    wall = time.perf_counter() - t0
    per_tier = {}
    for r in results:
        per_tier[r.tier] = per_tier.get(r.tier, 0) + 1
    lat = np.mean([r.latency_s for r in results])
    ttft = np.mean([r.ttft_s for r in results])
    split = " ".join(f"{t}={n}" for t, n in sorted(per_tier.items()))
    print(f"served {len(results)} requests | {split} | mean latency "
          f"{lat:.3f}s | mean ttft {ttft:.3f}s")
    dec = sum(e.decode_tokens for e in server.engines.values())
    pre = sum(e.prefill_tokens for e in server.engines.values())
    print(f"engine throughput: {dec / max(wall, 1e-9):.1f} decode tok/s, "
          f"{pre} prompt tokens prefilled (fused_steps={args.fused_steps})")
    for r in sorted(results, key=lambda r: r.rid)[:10]:
        print(f"  rid={r.rid} tier={r.tier:9s} routes={r.routes} "
              f"lat={r.latency_s:.3f}s")


if __name__ == "__main__":
    main()
