"""Serving launcher: MoA-Off scheduler + live engines on reduced models,
driven by a synthetic request stream through the unified cluster runtime.

Default is the paper's two-tier edge/cloud pair; ``--topology`` selects any
registered ``ClusterTopology`` (e.g. ``edge-regional-cloud``) and spins up
one reduced-model engine per tier. ``--arrival-rate`` paces arrivals as an
open-loop Poisson process, and ``--hedge-after`` / ``--fail-rate`` exercise
straggler hedging and snapshot/restore fault recovery against the live
engines (the same lifecycle the simulator models virtually). ``--fault-plan``
injects a deterministic chaos schedule (timed crash/slow/degrade/flap
windows), and ``--quarantine-after`` / ``--retry-backoff`` / ``--shed``
enable the tier-health circuit breaker, retry backoff and deadline-aware
load shedding. ``--byzantine`` adds message-level wire faults (frame
corruption, drops, dups, reorders — all detected by checksums and the
exactly-once delivery ledger) and ``--audit`` runs the runtime invariant
auditor at completion.

PYTHONPATH=src python -m repro.launch.serve --requests 16 --bandwidth 300e6
PYTHONPATH=src python -m repro.launch.serve --topology edge-regional-cloud
PYTHONPATH=src python -m repro.launch.serve --arrival-rate 4 --hedge-after 1
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

from repro.config import (TOPOLOGIES, ResilienceConfig, ServingConfig,
                          SpecConfig, get_topology)
from repro.data.synthetic import make_image
from repro.serving.faults import FaultPlan
from repro.serving.tiers import (ClusterServer, build_cluster_engines,
                                 build_engine_pools)

build_engines = build_cluster_engines  # legacy alias


def parse_replicas(specs) -> dict:
    """Parse repeated ``--replicas tier=N`` flags into {tier: N}."""
    out = {}
    for spec in specs or ():
        tier, _, n = spec.partition("=")
        if not tier or not n.isdigit() or int(n) < 1:
            raise SystemExit(f"--replicas expects tier=N with N >= 1, "
                             f"got {spec!r}")
        out[tier] = int(n)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--bandwidth", type=float, default=None,
                    help="override every remote uplink (bps); default keeps "
                         "the topology's declared links")
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fused-steps", type=int, default=8,
                    help="decode steps fused per host call (1 = legacy "
                         "per-token path)")
    ap.add_argument("--decode-impl", default="auto",
                    choices=["auto", "xla", "pallas"],
                    help="decode attention backend (auto = Pallas on TPU)")
    ap.add_argument("--topology", default="edge-cloud",
                    choices=sorted(TOPOLOGIES),
                    help="cluster topology to serve (one engine per tier)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in req/s; 0 = "
                         "submit everything immediately (closed batch)")
    ap.add_argument("--hedge-after", type=float, default=0.0,
                    help="clone a still-queued request onto the least-"
                         "loaded other tier after this many seconds "
                         "(first finisher wins, loser is cancelled)")
    ap.add_argument("--fail-rate", type=float, default=0.0,
                    help="probability an enqueued request kills its node; "
                         "the engine is rebuilt from its last snapshot")
    ap.add_argument("--fault-plan", default=None, metavar="JSON",
                    help="deterministic chaos schedule: inline JSON (or a "
                         "path to a JSON file) of timed crash/slow/degrade/"
                         "flap windows — see repro.serving.faults.FaultPlan")
    ap.add_argument("--byzantine", default=None, metavar="JSON",
                    help="byzantine wire-fault schedule: inline JSON (or a "
                         "path) of corrupt/msg_drop/msg_dup/msg_reorder "
                         "windows, merged into --fault-plan; or "
                         "'storm[:SEED]' for the canned whole-run storm")
    ap.add_argument("--audit", action="store_true",
                    help="run the InvariantAuditor at completion: "
                         "exactly-once outcomes, clean delivery ledgers, "
                         "no stuck stations, KV page conservation")
    ap.add_argument("--retry-backoff", action="store_true",
                    help="capped exponential backoff with deterministic "
                         "jitter between fault retries (instead of "
                         "immediate re-enqueue)")
    ap.add_argument("--shed", action="store_true",
                    help="load shedding: fail a request up front (terminal "
                         "'shed' outcome) when it is already past its SLO "
                         "at first enqueue or at a retry")
    ap.add_argument("--quarantine-after", type=int, default=0,
                    help="open a tier's circuit breaker after this many "
                         "consecutive service failures and re-route its "
                         "traffic to the best available tier until a probe "
                         "succeeds (0 = health tracking off)")
    ap.add_argument("--migrate", action="store_true",
                    help="cross-tier KV migration: hedged clones of "
                         "in-service stragglers receive the donor's "
                         "extracted cache slot instead of re-prefilling, "
                         "and fault recovery re-homes in-flight slots onto "
                         "surviving compatible tiers")
    ap.add_argument("--hedge-in-service", action="store_true",
                    help="hedge mid-decode stragglers too (speculative "
                         "backup clones; with --migrate they receive the "
                         "donor's cache rows instead of re-prefilling)")
    ap.add_argument("--migrate-threshold", type=int, default=0,
                    help="preempt-migrate the in-service request with the "
                         "most remaining decode work when a tier's "
                         "occupancy (active + queued) reaches this value "
                         "(0 = off; implies --migrate)")
    ap.add_argument("--speculate", default=None, metavar="DRAFT:TARGET",
                    help="cross-tier speculative decoding: the DRAFT tier "
                         "drafts token blocks that the TARGET tier verifies "
                         "in one batched decode step (e.g. edge:cloud); "
                         "requests fused on TARGET speculate when the "
                         "acceptance-rate EWMA clears SpecConfig.min_accept")
    ap.add_argument("--draft-k", type=int, default=8,
                    help="speculative draft block length (tokens drafted "
                         "per verify round; only with --speculate)")
    ap.add_argument("--slo", type=float, default=5.0,
                    help="per-request SLO in seconds (drives EDF admission "
                         "and the on-time/goodput accounting)")
    ap.add_argument("--prefix-cache-mb", type=float, default=0.0,
                    help="per-engine prefix store budget in MB: prompts "
                         "extending a cached prefix (e.g. a shared system "
                         "prompt) copy its KV rows and prefill only the "
                         "suffix (0 = off)")
    ap.add_argument("--sessions", type=int, default=0,
                    help="serve a multi-turn chat workload instead of "
                         "single-shot requests: this many sessions of "
                         "--turns turns each (shared system prompt); a "
                         "finished turn's KV parks on its tier and the "
                         "next turn resumes it, prefilling only the new "
                         "tokens")
    ap.add_argument("--turns", type=int, default=3,
                    help="turns per session (with --sessions)")
    ap.add_argument("--session-move-threshold", type=int, default=0,
                    help="ship a parked session to the scheduler's "
                         "preferred compatible tier when the parked tier "
                         "is this much deeper in occupancy (0 = always "
                         "sticky)")
    ap.add_argument("--max-seq", type=int, default=128,
                    help="engine cache capacity (grow it for long "
                         "multi-turn histories)")
    ap.add_argument("--paged", dest="paged", action="store_true",
                    default=True,
                    help="paged KV pool with continuous admission and "
                         "copy-free CoW prefix sharing (launcher default)")
    ap.add_argument("--no-paged", dest="paged", action="store_false",
                    help="escape hatch: dense per-slot KV cache")
    ap.add_argument("--kv-page-size", type=int, default=64,
                    help="KV rows per physical page (power of two dividing "
                         "--max-seq; with --paged)")
    ap.add_argument("--replicas", action="append", metavar="TIER=N",
                    help="replicate a tier's engine N ways behind a "
                         "load-balanced pool (repeatable, e.g. "
                         "--replicas edge=2 --replicas cloud=4); "
                         "unlisted tiers keep one replica")
    ap.add_argument("--transport", default="local",
                    choices=["local", "process"],
                    help="replica execution: 'local' steps every replica "
                         "in this process (parity/debug baseline); "
                         "'process' runs each replica in its own worker "
                         "process behind the message transport")
    ap.add_argument("--idle-poll", type=float, default=0.0,
                    help="idle-wait cap in seconds for the serving loop "
                         "(0 = event-driven: sleep until the next "
                         "scheduled event)")
    args = ap.parse_args()

    sv = ServingConfig(max_batch=args.max_batch, max_seq=args.max_seq,
                       fused_steps=args.fused_steps,
                       decode_impl=args.decode_impl,
                       prefix_cache_mb=args.prefix_cache_mb,
                       paged=args.paged, kv_page_size=args.kv_page_size,
                       idle_poll_s=args.idle_poll)
    topo = get_topology(args.topology)
    if args.bandwidth is not None:
        topo = dataclasses.replace(topo, tiers=tuple(
            dataclasses.replace(t, uplink_bps=args.bandwidth)
            if t.is_remote else t for t in topo.tiers))
    print(f"topology {topo.name}: tiers {', '.join(topo.names)}")
    plan = None
    if args.fault_plan:
        raw = args.fault_plan
        if os.path.exists(raw):
            raw = open(raw).read()
        plan = FaultPlan.from_json(raw)
    if args.byzantine:
        raw = args.byzantine
        if raw.startswith("storm"):
            _, _, s = raw.partition(":")
            byz = FaultPlan.byzantine_storm(seed=int(s) if s else args.seed)
        else:
            if os.path.exists(raw):
                raw = open(raw).read()
            byz = FaultPlan.from_json(raw)
        if plan is None:
            plan = byz
        else:  # merge wire faults into the timed chaos schedule
            plan = FaultPlan(list(plan.events) + list(byz.events),
                             fail_rate=plan.fail_rate,
                             wire_seed=byz.wire_seed or plan.wire_seed)
        kinds = sorted({e.kind for e in byz.events})
        print(f"byzantine wires: {', '.join(kinds)} "
              f"(wire_seed={plan.wire_seed})")
    resilience = None
    if args.quarantine_after > 0 or args.retry_backoff or args.shed:
        resilience = ResilienceConfig(
            health=args.quarantine_after > 0,
            quarantine_after=max(args.quarantine_after, 1),
            retry_backoff=args.retry_backoff, shed=args.shed)
    spec = None
    if args.speculate:
        draft, sep, target = args.speculate.partition(":")
        if not sep or not draft or not target:
            raise SystemExit("--speculate wants DRAFT:TARGET, e.g. "
                             "edge:cloud")
        for name in (draft, target):
            if name not in topo.names:
                raise SystemExit(f"--speculate names unknown tier {name!r} "
                                 f"(topology has {list(topo.names)})")
        spec = SpecConfig(draft_tier=draft, target_tier=target,
                          draft_k=args.draft_k)
        print(f"speculative decoding: {draft} drafts k={args.draft_k}, "
              f"{target} verifies")
    reps = parse_replicas(args.replicas)
    unknown = set(reps) - set(topo.names)
    if unknown:
        raise SystemExit(f"--replicas names unknown tiers {sorted(unknown)} "
                         f"(topology has {list(topo.names)})")
    if reps or args.transport != "local":
        # replicated pools; unlisted tiers keep the launcher's historical
        # single engine (TierSpec.servers stays a bench/model-level knob)
        counts = {name: reps.get(name, 1) for name in topo.names}
        engines = build_engine_pools(topo, sv, replicas=counts,
                                     transport=args.transport)
        rep_str = " ".join(f"{t}x{n}" for t, n in sorted(counts.items()))
        print(f"replicas: {rep_str} | transport {args.transport}")
    else:
        engines = build_engines(topo, sv)
    server = ClusterServer(engines, topology=topo,
                           hedge_after_s=args.hedge_after,
                           fail_rate=args.fail_rate, migrate=args.migrate,
                           migrate_threshold=args.migrate_threshold,
                           hedge_in_service=args.hedge_in_service,
                           sessions=args.sessions > 0,
                           session_move_threshold=args.session_move_threshold,
                           fault_plan=plan, resilience=resilience, spec=spec,
                           audit=args.audit)

    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    if args.sessions > 0:
        system = "you are a Helpful assistant; answer with Care. "
        for turn in range(args.turns):
            delay = 0.0
            for s in range(args.sessions):
                if args.arrival_rate > 0:
                    delay += rng.exponential(1.0 / args.arrival_rate)
                text = (system if turn == 0 else "") + (
                    f"turn {turn}: tell me more about Topic {s}. ")
                server.submit_turn(f"chat-{s}", text, max_new=args.max_new,
                                   slo_s=args.slo, delay_s=delay)
            server.run()  # turns of one session are sequential
        results = server.results
    else:
        delay = 0.0
        for i in range(args.requests):
            u = rng.beta(1.6, 1.6)
            img = make_image(rng, u, 64, 64)
            text = (f"Request {i}: describe the Scene {i * 3}. "
                    + "and then explain why it matters. "
                    * rng.integers(1, 12))
            if args.arrival_rate > 0:
                delay += rng.exponential(1.0 / args.arrival_rate)
            server.submit(text, image=img, max_new=args.max_new,
                          slo_s=args.slo, delay_s=delay)
        results = server.run()
    wall = time.perf_counter() - t0
    per_tier = {}
    for r in results:
        per_tier[r.tier] = per_tier.get(r.tier, 0) + 1
    lat = np.mean([r.latency_s for r in results])
    ttft = np.mean([r.ttft_s for r in results])
    on_time = sum(r.on_time for r in results)
    split = " ".join(f"{t}={n}" for t, n in sorted(per_tier.items()))
    print(f"served {len(results)} requests | {split} | mean latency "
          f"{lat:.3f}s | mean ttft {ttft:.3f}s | {on_time}/{len(results)} "
          f"within SLO | goodput {on_time / max(wall, 1e-9):.2f} req/s")
    hedged = sum(r.hedged for r in results)
    retries = sum(r.retries for r in results)
    trunc = sum(r.truncated for r in results)
    if hedged or retries or trunc:
        print(f"hedged={hedged} retries={retries} truncated={trunc} "
              f"engine restores={server.backend.restores}")
    failed = sum(r.failed for r in results)
    if plan is not None or resilience is not None or failed:
        shed = sum(r.fail_reason == "shed" for r in results)
        degraded = sum(r.degraded for r in results)
        ok = sum((not r.failed) and r.on_time for r in results)
        health = server.runtime.health
        states = (" ".join(f"{t}={s}" for t, s in
                           sorted(health.snapshot().items()))
                  if health is not None else "off")
        print(f"resilience: failed={failed - shed} shed={shed} "
              f"degraded={degraded} | goodput {ok}/{len(results)} | "
              f"quarantines={health.quarantine_count if health else 0} "
              f"rescued-sessions={server.runtime.rescued_sessions} | "
              f"health {states}")
    if server.runtime.migrate:
        mig = sum(r.migrated for r in results)
        mb = sum(r.migration_bytes for r in results)
        print(f"migrated={mig} requests ({server.runtime.migrations} slot "
              f"moves, {mb / 1e6:.2f} MB of cache rows shipped)")
    if args.sessions > 0 or args.prefix_cache_mb > 0:
        resumed = sum(r.warm == "resume" for r in results)
        hits = sum(r.warm == "prefix" for r in results)
        saved = sum(r.warm_tokens for r in results)
        print(f"sessions: {resumed} resumed turns, {hits} prefix hits, "
              f"{saved:.0f} cached tokens never re-prefilled, "
              f"{server.runtime.session_moves} parked-state moves")
    ws = server.runtime.wire_stats
    if args.byzantine or args.audit or ws:
        print(f"wire: corruptions {ws.get('corrupt_detected', 0)}/"
              f"{ws.get('corrupt_injected', 0)} detected "
              f"(undetected={ws.get('corrupt_undetected', 0)}) | "
              f"dropped={ws.get('msgs_dropped', 0)} "
              f"duped={ws.get('msgs_duped', 0)} "
              f"reordered={ws.get('msgs_reordered', 0)} | "
              f"dups suppressed={ws.get('dups_suppressed', 0)} "
              f"dup finishes={ws.get('dup_finishes_suppressed', 0)} "
              f"resyncs={ws.get('resyncs', 0)}")
    if args.audit:
        verdict = server.runtime.auditor.last
        if verdict.get("clean"):
            print(f"audit: CLEAN ({verdict['requests']} requests, "
                  f"{verdict['outcomes']} outcomes, every invariant held)")
        else:
            print(f"audit: {len(verdict.get('violations', []))} "
                  f"VIOLATION(S)")
            for v in verdict.get("violations", []):
                print(f"  ! {v}")
    if spec is not None:
        drafted = sum(o.drafted_tokens for o in server.runtime.outcomes)
        accepted = sum(o.accepted_tokens for o in server.runtime.outcomes)
        spun = sum(o.drafted_tokens > 0 for o in server.runtime.outcomes)
        rate = accepted / drafted if drafted else 0.0
        print(f"speculation: {spun}/{len(results)} requests drafted on "
              f"{spec.draft_tier} | {accepted}/{drafted} draft tokens "
              f"accepted ({rate:.0%})")
    dec = sum(p.decode_tokens for p in server.pools.values())
    pre = sum(p.prefill_tokens for p in server.pools.values())
    enc = sum(p.encode_tokens for p in server.pools.values())
    print(f"engine throughput: {dec / max(wall, 1e-9):.1f} decode tok/s, "
          f"{pre} prompt tokens prefilled, {enc} patch tokens encoded "
          f"({server.backend.offloaded_encodes} images encoded off-fusion; "
          f"fused_steps={args.fused_steps})")
    for tier, pool in sorted(server.pools.items()):
        if len(pool) == 1 and pool.transports[0].kind == "local":
            continue  # unreplicated local tier: nothing pool-level to add
        rows = " | ".join(
            f"r{s['replica']}[{s['kind'][0]}]"
            f"{' DEAD' if not s['alive'] else ''} "
            f"active={s['active']}/{s['slots']} queue={s['queue']} "
            f"kv={s['kv_headroom']:.2f} dec={s['decode_tokens']}"
            for s in pool.replica_stats())
        print(f"  replicas[{tier}]: {rows}")
    if args.paged:
        for tier, pool in sorted(server.pools.items()):
            for i, tr in enumerate(pool.transports):
                if tr.kind != "local":
                    continue  # gauges live in the worker process
                g = tr.engine.kv_gauges()
                name = tier if len(pool) == 1 else f"{tier}/{i}"
                print(f"  kv[{name}]: {g['pages_free']}/{g['pages_total']} "
                      f"pages free, {g['pages_shared']} shared (CoW), "
                      f"high-water {g['pages_high_water']} "
                      f"({g['pages_high_water'] * g['page_bytes'] / 1e6:.2f} "
                      f"MB peak)")
    for r in sorted(results, key=lambda r: r.rid)[:10]:
        flags = "".join(f" {f}" for f, on in
                        (("hedged", r.hedged), ("truncated", r.truncated),
                         (f"retries={r.retries}", r.retries)) if on)
        print(f"  rid={r.rid} tier={r.tier:9s} routes={r.routes} "
              f"lat={r.latency_s:.3f}s ttft={r.ttft_s:.3f}s{flags}")
    server.close()  # joins process-transport workers; no-op for local


if __name__ == "__main__":
    main()
