"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax

from repro.config import MULTI_POD_MESH, SINGLE_POD_MESH, MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices are available."""
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_config(multi_pod: bool) -> MeshConfig:
    return MULTI_POD_MESH if multi_pod else SINGLE_POD_MESH
