"""Deterministic toy tokenizer with token CLASSES.

Words hash into a fixed vocab; dedicated id ranges mark ENTITY tokens
(capitalized words, numbers) and SENTENCE terminators so the §3.1.2 text
complexity terms (entities/sentence, token count) are computable from token
ids alone — standing in for a production NER pass, with the same statistics.
"""
from __future__ import annotations

import re
from typing import List, Tuple

import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SENT_END_ID = 3  # ". ! ?"
ENTITY_BASE = 4  # entity/numeral tokens: [4, 4+ENTITY_RANGE)
ENTITY_RANGE = 996
WORD_BASE = 1000


class ToyTokenizer:
    def __init__(self, vocab_size: int = 32_000):
        assert vocab_size > WORD_BASE + 100
        self.vocab_size = vocab_size
        self._word_range = vocab_size - WORD_BASE

    def encode(self, text: str) -> List[int]:
        ids = [BOS_ID]
        for tok in re.findall(r"[A-Za-z]+|\d+|[.!?]", text):
            if tok in ".!?":
                ids.append(SENT_END_ID)
            elif tok[0].isupper() or tok.isdigit():
                ids.append(ENTITY_BASE + (hash(tok) % ENTITY_RANGE))
            else:
                ids.append(WORD_BASE + (hash(tok) % self._word_range))
        ids.append(EOS_ID)
        return ids

    @staticmethod
    def is_entity(ids: np.ndarray) -> np.ndarray:
        return (ids >= ENTITY_BASE) & (ids < ENTITY_BASE + ENTITY_RANGE)

    @staticmethod
    def is_sentence_end(ids: np.ndarray) -> np.ndarray:
        return ids == SENT_END_ID

    def pad(self, ids: List[int], length: int) -> np.ndarray:
        out = np.full((length,), PAD_ID, np.int32)
        out[: min(len(ids), length)] = ids[:length]
        return out
