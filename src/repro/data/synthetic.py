"""Synthetic multimodal data with CONTROLLED complexity.

The paper evaluates on VQAv2/MMBench images; offline we generate parametric
images whose §3.1.1 indicators (edge density, entropy, sharpness) are driven
by a single latent ``content`` knob u ∈ [0,1] — this lets benchmarks sweep
the estimator's whole operating range and ties request difficulty to what
the scorer can actually observe (plus noise), mirroring real data.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.request import ModalityInput, Request


def make_image(rng: np.random.Generator, content: float, h: int = 256,
               w: int = 256) -> np.ndarray:
    """One grayscale image in [0,255]; higher ``content`` => more edges,
    texture entropy and sharpness (all §3.1.1 indicators move together)."""
    u = float(np.clip(content, 0.0, 1.0))
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    img = 128.0 + 40.0 * np.sin(2 * np.pi * xx / max(w, 1))
    # edge structure: superimposed gratings whose frequency grows with u
    for k in range(1, 2 + int(6 * u)):
        f = 2.0 ** k
        img += (30.0 * u) * np.sign(np.sin(2 * np.pi * (xx + yy) * f / w))
    # texture: white noise amplitude grows with u
    img += rng.normal(0.0, 5.0 + 55.0 * u, (h, w)).astype(np.float32)
    # low-content images are additionally blurred (lower Laplacian variance)
    if u < 0.5:
        kdim = 1 + 2 * int((0.5 - u) * 8)
        if kdim > 1:
            kern = np.ones(kdim, np.float32) / kdim
            img = np.apply_along_axis(
                lambda r: np.convolve(r, kern, mode="same"), 1, img)
            img = np.apply_along_axis(
                lambda c: np.convolve(c, kern, mode="same"), 0, img)
    return np.clip(img, 0, 255).astype(np.float32)


def synth_image_batch(rng: np.random.Generator, contents, h=256, w=256):
    return np.stack([make_image(rng, c, h, w) for c in contents])


def make_text_meta(rng: np.random.Generator, content: float) -> Dict[str, float]:
    """Token/entity/sentence counts whose §3.1.2 score tracks ``content``.

    VQA-style prompts: mostly short questions (quadratic in the latent so the
    mass sits low), occasionally long multi-entity instructions.
    """
    u = float(np.clip(content, 0.0, 1.0))
    tokens = int(16 + u * u * 900 + rng.integers(0, 24))
    sentences = max(1, tokens // 24)
    entities = int(sentences * u * 3.0 + rng.integers(0, 2))
    return {"tokens": tokens, "entities": entities, "sentences": sentences}


class RequestGenerator:
    """Poisson stream of multimodal requests for the simulator/engine.

    difficulty = mean of the latent modality contents + noise: the scorer sees
    only the realized payloads, never the latent — exactly the deployment
    situation (complexity is a PROXY for difficulty).
    """

    def __init__(self, seed: int = 0, arrival_rate: float = 20.0,
                 image_hw: int = 256, materialize_images: bool = False,
                 p_image: float = 0.95, decode_tokens: int = 64,
                 slo_s: float = 8.0):
        self.rng = np.random.default_rng(seed)
        self.rate = arrival_rate
        self.hw = image_hw
        self.materialize = materialize_images
        self.p_image = p_image
        self.decode_tokens = decode_tokens
        self.slo_s = slo_s

    def generate(self, n: int) -> List[Request]:
        t = 0.0
        out = []
        for rid in range(n):
            t += self.rng.exponential(1.0 / self.rate)
            u_img = self.rng.beta(1.6, 1.6)  # latent image content
            u_txt = self.rng.beta(1.4, 2.2)  # text skews simpler
            mods: Dict[str, ModalityInput] = {}
            if self.rng.random() < self.p_image:
                # resolution is INDEPENDENT of content difficulty (a big photo
                # isn't a hard question) — size-based routing (PerLLM's
                # constraint) therefore carries no difficulty signal, while
                # the §3.1.1 complexity score blends content + resolution
                v_size = self.rng.beta(2.0, 2.0)
                hw = int(384 + 896 * v_size)
                c_img = float(np.clip(0.8 * u_img + 0.2 * v_size
                                      + self.rng.normal(0, 0.03), 0, 1))
                if self.materialize:
                    img = make_image(self.rng, u_img, self.hw, self.hw)
                    mods["image"] = ModalityInput(
                        "image", data=img,
                        size_bytes=int(img.size * 0.5),  # ~jpeg-ish
                        meta={"h": self.hw, "w": self.hw, "content_c": u_img})
                else:
                    mods["image"] = ModalityInput(
                        "image", size_bytes=int(hw * hw * 1.2),  # ~jpeg q90
                        meta={"h": hw, "w": hw, "content_c": c_img})
            tmeta = make_text_meta(self.rng, u_txt)
            mods["text"] = ModalityInput(
                "text", meta=tmeta, size_bytes=int(tmeta["tokens"] * 4))
            # VQA-style: the image carries most of the task difficulty
            if "image" in mods:
                base = 0.75 * u_img + 0.25 * u_txt
            else:
                base = u_txt
            difficulty = float(np.clip(
                base + self.rng.normal(0, 0.06), 0, 1))
            out.append(Request(rid=rid, arrival_s=t, modalities=mods,
                               decode_tokens=self.decode_tokens,
                               difficulty=difficulty, slo_s=self.slo_s))
        return out


def make_token_batch(rng: np.random.Generator, batch: int, seq: int,
                     vocab: int) -> Dict[str, np.ndarray]:
    """Deterministic synthetic LM batch (Zipf-ish marginals + shift labels)."""
    z = rng.zipf(1.3, size=(batch, seq + 1))
    toks = (z % (vocab - 4) + 4).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
