from repro.data.synthetic import (  # noqa: F401
    RequestGenerator, make_image, make_token_batch, synth_image_batch)
from repro.data.tokenizer import ToyTokenizer  # noqa: F401
