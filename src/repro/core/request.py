"""Request/response dataclasses shared by the scheduler, engine and simulator."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

MODALITIES = ("image", "text", "audio")


@dataclass
class ModalityInput:
    kind: str  # image | text | audio
    data: Optional[Any] = None  # real payload (live serving path)
    meta: Dict[str, float] = field(default_factory=dict)  # h/w/tokens/entities…
    size_bytes: int = 0
    complexity: Optional[float] = None  # filled by the modality-aware module

    def __post_init__(self):
        assert self.kind in MODALITIES, self.kind


@dataclass
class Request:
    rid: int
    arrival_s: float
    modalities: Dict[str, ModalityInput]
    decode_tokens: int = 64
    # latent per-request difficulty in [0,1] — simulator ground truth used by
    # the accuracy model; NOT visible to the policy (it only sees complexity)
    difficulty: float = 0.5
    slo_s: float = 5.0

    def total_bytes(self) -> int:
        return sum(m.size_bytes for m in self.modalities.values())


@dataclass
class Decision:
    """Per-modality routing (Eq. 6) + bookkeeping for the ablation study."""

    routes: Dict[str, str]  # modality -> "edge" | "cloud"
    taus: Dict[str, float] = field(default_factory=dict)
    reason: str = ""

    @property
    def any_cloud(self) -> bool:
        return any(r == "cloud" for r in self.routes.values())

    @property
    def all_edge(self) -> bool:
        return not self.any_cloud


@dataclass
class Outcome:
    rid: int
    latency_s: float
    routes: Dict[str, str]
    correct: bool
    edge_flops: float = 0.0
    cloud_flops: float = 0.0
    edge_mem_bytes: float = 0.0
    cloud_mem_bytes: float = 0.0
    transfer_bytes: float = 0.0
    hedged: bool = False
    retries: int = 0
