"""Request/response dataclasses shared by the scheduler, engine and simulator.

Also defines the request LIFECYCLE the cluster runtime drives — one state
machine for the analytic simulator and the live server:

    arrival -> routed -> [encode:<modality> per off-fusion modality]
            -> [transfer per remote link] -> enqueue -> serve -> complete
    (+ ``hedged`` / ``retry`` / ``preempt``+``migrate`` edges)

``RequestRecord`` is the per-request ledger (shared by hedged twins — the
single ``done`` cell guarantees exactly one Outcome per request);``Job`` is
one serving *attempt* of a request on one tier (the hedge clone is a second
Job pointing at the same record). Together they retire the ad-hoc job dict
the simulator used to thread through its event handlers.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, List, Optional, Tuple

MODALITIES = ("image", "text", "audio")

#: canonical lifecycle states, identical across execution backends (the
#: sim-vs-live parity test compares these traces, timing aside).
#: ``sticky``/``session_move`` are session-routing decisions made at
#: arrival; ``prefix``/``resume`` mark warm (suffix-only) admissions and
#: ``park`` marks a finished turn's state being retained for the next one.
#: Degradation edges: ``degraded`` (re-routed off an unavailable tier),
#: ``quarantine`` (this request's failure opened the tier's circuit),
#: ``timeout`` (a WAN transfer was abandoned), and the terminal states
#: ``failed`` (retry budget exhausted) / ``shed`` (SLO provably unmeetable).
#: Speculative decoding marks one ``draft`` (draft tier) / ``verify`` /
#: ``accept`` (target tier) triplet per speculated request — one triplet,
#: not one per round, so analytic and live traces stay comparable.
LIFECYCLE = ("arrival", "routed", "sticky", "session_move", "encode",
             "transfer", "enqueue", "prefix", "resume", "serve", "draft",
             "verify", "accept", "hedged", "retry", "preempt", "migrate",
             "park", "degraded", "quarantine", "timeout", "shed", "failed",
             "complete")


@dataclass
class ModalityInput:
    kind: str  # image | text | audio
    data: Optional[Any] = None  # real payload (live serving path)
    meta: Dict[str, float] = field(default_factory=dict)  # h/w/tokens/entities…
    size_bytes: int = 0
    complexity: Optional[float] = None  # filled by the modality-aware module

    def __post_init__(self):
        assert self.kind in MODALITIES, self.kind


@dataclass
class Request:
    rid: int
    arrival_s: float
    modalities: Dict[str, ModalityInput]
    decode_tokens: int = 64
    # latent per-request difficulty in [0,1] — simulator ground truth used by
    # the accuracy model; NOT visible to the policy (it only sees complexity)
    difficulty: float = 0.5
    slo_s: float = 5.0
    # multi-turn session id: turns of one conversation share it. The runtime
    # routes a turn sticky-by-default to the tier holding the session's
    # parked KV, and the serving engine resumes the parked state instead of
    # re-prefilling the whole history.
    session: Optional[str] = None

    def total_bytes(self) -> int:
        return sum(m.size_bytes for m in self.modalities.values())


@dataclass
class Decision:
    """Per-modality routing (Eq. 6) + bookkeeping for the ablation study."""

    routes: Dict[str, str]  # modality -> tier name ("edge"/"cloud"/…)
    taus: Dict[str, float] = field(default_factory=dict)
    reason: str = ""
    # names of the topology's local tiers, stamped by the deciding policy
    local_tiers: FrozenSet[str] = frozenset({"edge"})
    # cross-tier speculative decoding: (draft_tier, target_tier, k, alpha)
    # stamped by the scheduler when the fusion tier matches the SpecConfig
    # target and the acceptance EWMA clears the gate; None = don't
    speculate: Optional[Tuple[str, str, int, float]] = None

    @property
    def any_cloud(self) -> bool:
        """Any modality routed off the local tier set. On the legacy
        two-tier topology this is exactly "any modality went cloud"; on an
        N-tier topology it means "some modality was offloaded to a remote
        tier" (use ``ClusterTopology.fusion_tier`` for the serving tier)."""
        return any(r not in self.local_tiers for r in self.routes.values())

    @property
    def all_edge(self) -> bool:
        return not self.any_cloud


@dataclass
class RequestRecord:
    """Per-request lifecycle ledger, shared by every serving attempt.

    ``events`` is the ordered (state, tier) trace — state names come from
    :data:`LIFECYCLE`; per-token streaming is deliberately NOT an event so
    analytic and live traces stay comparable. ``done`` is the single
    completion cell: whichever hedged twin finishes first flips it, and the
    loser's completion (or a replayed one after a fault restore) is dropped.
    """

    rid: int
    done: bool = False
    events: List[Tuple[str, str]] = field(default_factory=list)
    ttft_s: float = 0.0
    wan_s: float = 0.0  # time spent on WAN links before first enqueue
    truncated: bool = False
    migrated: bool = False  # some attempt's KV cache moved across tiers
    migration_bytes: float = 0.0  # total slot-payload bytes shipped
    warm: str = ""  # "prefix" | "resume" when admitted onto reused KV rows
    warm_tokens: float = 0.0  # cached tokens whose prefill was skipped
    degraded: bool = False  # re-routed off an unavailable/quarantined tier
    tokens: List[int] = field(default_factory=list)  # live: streamed tokens
    # speculative decoding: draft tokens proposed for / accepted by this
    # request's verify loop (0/0 when it was never speculated)
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    outcome: Optional["Outcome"] = None

    def mark(self, state: str, tier: str = "") -> None:
        self.events.append((state, tier))

    def trace(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(self.events)


@dataclass
class Job:
    """One serving attempt of a request on one tier.

    ``payload`` is backend scratch (cached analytic costs / tokenized live
    prompt+extras); the lifecycle fields themselves are typed. A hedge
    clone copies the Job (including the already-paid ``transfer_bytes`` —
    the single Outcome accounts for the original's WAN transfer even when
    the clone wins) but shares the ``record``.
    """

    request: Request
    decision: Decision
    fusion: str  # planned fusion tier (partial-offload discounts anchor here)
    tier: str  # serving tier of THIS attempt
    t_start: float
    record: RequestRecord
    retries: int = 0
    hedged: bool = False
    in_service: bool = False
    pending_transfers: int = 0
    transfer_bytes: float = 0.0
    payload: Dict[str, Any] = field(default_factory=dict)

    #: backend-internal migration/session bookkeeping that must never leak
    #: into a hedge clone (a stale ``preempted`` marker would swallow the
    #: clone's own completion event; a clone has no parked rows shipped
    #: for it, so in-flight session-move state must not ride along)
    _NO_CLONE_KEYS = ("preempted", "migration_wire", "migration_donor",
                      "migration_nbytes", "session_wire", "session_parked",
                      "session_pending")

    def clone(self, tier: str) -> "Job":
        payload = {k: v for k, v in self.payload.items()
                   if k not in self._NO_CLONE_KEYS}
        return dataclasses.replace(self, tier=tier, in_service=False,
                                   payload=payload)


@dataclass
class Outcome:
    """Per-request result with per-tier resource attribution.

    ``tier_flops`` / ``tier_mem_bytes`` are keyed by tier name; the legacy
    two-tier scalars remain readable as properties.
    """

    rid: int
    latency_s: float
    routes: Dict[str, str]
    correct: bool
    tier_flops: Dict[str, float] = field(default_factory=dict)
    tier_mem_bytes: Dict[str, float] = field(default_factory=dict)
    transfer_bytes: float = 0.0
    hedged: bool = False
    retries: int = 0
    served_tier: str = ""  # tier that ran the fused generation
    ttft_s: float = 0.0  # time to first streamed token (live backends)
    on_time: bool = True  # finished within the request's SLO
    truncated: bool = False  # prompt clipped to the engine budget (live)
    migrated: bool = False  # KV cache moved across tiers mid-flight
    migration_bytes: float = 0.0  # slot-payload bytes shipped for this request
    warm: str = ""  # "prefix" | "resume": admitted onto reused KV rows
    warm_tokens: float = 0.0  # cached tokens whose prefill was skipped
    # graceful degradation: a request that could NOT be served ends in a
    # terminal failed Outcome instead of silently vanishing
    failed: bool = False  # terminal: never completed
    fail_reason: str = ""  # "retries" | "shed" | "" (completed)
    degraded: bool = False  # served, but re-routed off an unavailable tier
    # speculative decoding (0/0 when the request was never speculated)
    drafted_tokens: int = 0  # draft-tier proposals shipped for this request
    accepted_tokens: int = 0  # proposals the target verified and committed

    @property
    def edge_flops(self) -> float:
        return self.tier_flops.get("edge", 0.0)

    @property
    def cloud_flops(self) -> float:
        return self.tier_flops.get("cloud", 0.0)

    @property
    def edge_mem_bytes(self) -> float:
        return self.tier_mem_bytes.get("edge", 0.0)

    @property
    def cloud_mem_bytes(self) -> float:
        return self.tier_mem_bytes.get("cloud", 0.0)
