"""Request/response dataclasses shared by the scheduler, engine and simulator."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Optional

MODALITIES = ("image", "text", "audio")


@dataclass
class ModalityInput:
    kind: str  # image | text | audio
    data: Optional[Any] = None  # real payload (live serving path)
    meta: Dict[str, float] = field(default_factory=dict)  # h/w/tokens/entities…
    size_bytes: int = 0
    complexity: Optional[float] = None  # filled by the modality-aware module

    def __post_init__(self):
        assert self.kind in MODALITIES, self.kind


@dataclass
class Request:
    rid: int
    arrival_s: float
    modalities: Dict[str, ModalityInput]
    decode_tokens: int = 64
    # latent per-request difficulty in [0,1] — simulator ground truth used by
    # the accuracy model; NOT visible to the policy (it only sees complexity)
    difficulty: float = 0.5
    slo_s: float = 5.0

    def total_bytes(self) -> int:
        return sum(m.size_bytes for m in self.modalities.values())


@dataclass
class Decision:
    """Per-modality routing (Eq. 6) + bookkeeping for the ablation study."""

    routes: Dict[str, str]  # modality -> tier name ("edge"/"cloud"/…)
    taus: Dict[str, float] = field(default_factory=dict)
    reason: str = ""
    # names of the topology's local tiers, stamped by the deciding policy
    local_tiers: FrozenSet[str] = frozenset({"edge"})

    @property
    def any_cloud(self) -> bool:
        """Any modality routed off the local tier set. On the legacy
        two-tier topology this is exactly "any modality went cloud"; on an
        N-tier topology it means "some modality was offloaded to a remote
        tier" (use ``ClusterTopology.fusion_tier`` for the serving tier)."""
        return any(r not in self.local_tiers for r in self.routes.values())

    @property
    def all_edge(self) -> bool:
        return not self.any_cloud


@dataclass
class Outcome:
    """Per-request result with per-tier resource attribution.

    ``tier_flops`` / ``tier_mem_bytes`` are keyed by tier name; the legacy
    two-tier scalars remain readable as properties.
    """

    rid: int
    latency_s: float
    routes: Dict[str, str]
    correct: bool
    tier_flops: Dict[str, float] = field(default_factory=dict)
    tier_mem_bytes: Dict[str, float] = field(default_factory=dict)
    transfer_bytes: float = 0.0
    hedged: bool = False
    retries: int = 0
    served_tier: str = ""  # tier that ran the fused generation

    @property
    def edge_flops(self) -> float:
        return self.tier_flops.get("edge", 0.0)

    @property
    def cloud_flops(self) -> float:
        return self.tier_flops.get("cloud", 0.0)

    @property
    def edge_mem_bytes(self) -> float:
        return self.tier_mem_bytes.get("edge", 0.0)

    @property
    def cloud_mem_bytes(self) -> float:
        return self.tier_mem_bytes.get("cloud", 0.0)
