"""System-state tracking for the offloading policy: s = (ℓ_t, b) of Eq. 5/6.

Generalized to an N-tier cluster: load and queue-depth EWMAs are kept per
tier name; the two-tier quantities of the paper (``edge_load``,
``cloud_load``, …) remain available as property views onto the dicts, so all
legacy call sites and the Eq. 5 gate read the same numbers they always did.
"""
from __future__ import annotations

from typing import Deque, Dict, List, Optional
import collections

EDGE_TIER, CLOUD_TIER = "edge", "cloud"


class SystemState:
    """Per-tier system state with legacy two-tier accessors.

    Constructor keeps the historical field order
    ``(edge_load, bandwidth_bps, cloud_load, queue_depth_edge,
    queue_depth_cloud)`` so existing positional/keyword call sites work
    unchanged; N-tier callers pass ``loads`` / ``queue_depths`` /
    ``bandwidths`` dicts keyed by tier name.
    """

    def __init__(self, edge_load: float = 0.0, bandwidth_bps: float = 300e6,
                 cloud_load: float = 0.0, queue_depth_edge: int = 0,
                 queue_depth_cloud: int = 0, *,
                 loads: Optional[Dict[str, float]] = None,
                 queue_depths: Optional[Dict[str, int]] = None,
                 bandwidths: Optional[Dict[str, float]] = None):
        self.loads: Dict[str, float] = {EDGE_TIER: float(edge_load),
                                        CLOUD_TIER: float(cloud_load)}
        self.queue_depths: Dict[str, int] = {
            EDGE_TIER: int(queue_depth_edge),
            CLOUD_TIER: int(queue_depth_cloud)}
        if loads:
            self.loads.update({k: float(v) for k, v in loads.items()})
        if queue_depths:
            self.queue_depths.update({k: int(v)
                                      for k, v in queue_depths.items()})
        # scalar b of Eq. 5 (the edge<->cloud WAN); per-tier uplinks optional
        self.bandwidth_bps = float(bandwidth_bps)
        self.bandwidths: Dict[str, float] = (
            {k: float(v) for k, v in bandwidths.items()} if bandwidths else {})
        # cache-affinity signal: parked multi-turn sessions per tier (their
        # next turns will stick there, i.e. near-future load the raw queue
        # depths don't show yet)
        self.parked_sessions: Dict[str, int] = {}
        # real per-tier KV headroom (free fraction of the paged KV pool,
        # [0, 1]): finer-grained than slot occupancy — a tier can have free
        # slots but no pages (long contexts) or free pages but no slots
        self.kv_headroom: Dict[str, float] = {}
        # circuit-breaker states from the runtime's HealthMonitor (tier ->
        # "healthy" | "suspect" | "quarantined" | "probing"); empty when
        # the health layer is off — every tier then reads as healthy
        self.health: Dict[str, str] = {}
        # per-replica occupancy vectors for replicated tiers (tier ->
        # [load per replica], a dead replica reads 1.0); empty on
        # single-engine backends and the analytic simulator
        self.replica_loads: Dict[str, List[float]] = {}
        # speculative-decoding acceptance-rate EWMA (accepted/drafted of
        # finished verify loops); None until the first observation — the
        # scheduler then falls back to SpecConfig.init_accept
        self.spec_accept: Optional[float] = None

    # -- per-tier access ----------------------------------------------------

    def load(self, tier: str) -> float:
        return self.loads.get(tier, 0.0)

    def parked(self, tier: str) -> int:
        return self.parked_sessions.get(tier, 0)

    def kv(self, tier: str) -> float:
        """KV-pool headroom toward ``tier`` (1.0 when untracked)."""
        return self.kv_headroom.get(tier, 1.0)

    def queue_depth(self, tier: str) -> int:
        return self.queue_depths.get(tier, 0)

    def replicas(self, tier: str) -> List[float]:
        """Per-replica occupancy toward ``tier`` ([] when unreplicated)."""
        return self.replica_loads.get(tier, [])

    def replica_imbalance(self, tier: str) -> float:
        """Spread between the busiest and idlest replica (0 when the tier
        has fewer than two replicas — nothing to balance)."""
        reps = self.replica_loads.get(tier)
        if not reps or len(reps) < 2:
            return 0.0
        return max(reps) - min(reps)

    def healthy(self, tier: str) -> bool:
        """False only when the tier's circuit is OPEN (quarantined/probing
        admit no regular traffic); untracked tiers read healthy."""
        return self.health.get(tier, "healthy") not in ("quarantined",
                                                        "probing")

    def bandwidth_to(self, tier: str) -> float:
        """Uplink bandwidth toward ``tier`` (the global b when untracked)."""
        return self.bandwidths.get(tier, self.bandwidth_bps)

    # -- legacy two-tier views ---------------------------------------------

    @property
    def edge_load(self) -> float:
        return self.loads.get(EDGE_TIER, 0.0)

    @edge_load.setter
    def edge_load(self, v: float) -> None:
        self.loads[EDGE_TIER] = float(v)

    @property
    def cloud_load(self) -> float:
        return self.loads.get(CLOUD_TIER, 0.0)

    @cloud_load.setter
    def cloud_load(self, v: float) -> None:
        self.loads[CLOUD_TIER] = float(v)

    @property
    def queue_depth_edge(self) -> int:
        return self.queue_depths.get(EDGE_TIER, 0)

    @queue_depth_edge.setter
    def queue_depth_edge(self, v: int) -> None:
        self.queue_depths[EDGE_TIER] = int(v)

    @property
    def queue_depth_cloud(self) -> int:
        return self.queue_depths.get(CLOUD_TIER, 0)

    @queue_depth_cloud.setter
    def queue_depth_cloud(self, v: int) -> None:
        self.queue_depths[CLOUD_TIER] = int(v)

    def __repr__(self) -> str:
        return (f"SystemState(loads={self.loads}, "
                f"queues={self.queue_depths}, "
                f"bandwidth_bps={self.bandwidth_bps:.3g})")


class StateEstimator:
    """EWMA smoothing of raw observations (load spikes shouldn't thrash τ)."""

    def __init__(self, alpha: float = 0.2,
                 init: Optional[SystemState] = None):
        self.alpha = alpha
        self.state = init or SystemState()
        self._lat_window: Deque[float] = collections.deque(maxlen=256)

    def observe_load(self, tier: str, load: float) -> None:
        a = self.alpha
        prev = self.state.loads.get(tier, 0.0)
        self.state.loads[tier] = (1 - a) * prev + a * float(load)

    def observe_edge_load(self, load: float) -> None:
        self.observe_load(EDGE_TIER, load)

    def observe_cloud_load(self, load: float) -> None:
        self.observe_load(CLOUD_TIER, load)

    def observe_bandwidth(self, bps: float,
                          tier: Optional[str] = None) -> None:
        a = self.alpha
        if tier is None:
            self.state.bandwidth_bps = ((1 - a) * self.state.bandwidth_bps
                                        + a * float(bps))
            return
        prev = self.state.bandwidths.get(tier, float(bps))
        self.state.bandwidths[tier] = (1 - a) * prev + a * float(bps)

    def observe_queues(self, edge: int, cloud: int) -> None:
        self.state.queue_depths[EDGE_TIER] = int(edge)
        self.state.queue_depths[CLOUD_TIER] = int(cloud)

    def observe_queue_depths(self, depths: Dict[str, int]) -> None:
        for tier, d in depths.items():
            self.state.queue_depths[tier] = int(d)

    def observe_parked_sessions(self, parked: Dict[str, int]) -> None:
        """Cache-affinity: parked sessions per tier (instantaneous counts,
        not smoothed — they are exact, not noisy samples)."""
        for tier, n in parked.items():
            self.state.parked_sessions[tier] = int(n)

    def observe_kv_headroom(self, kv: Dict[str, float]) -> None:
        """Per-tier KV-pool headroom (exact page counts, not smoothed)."""
        for tier, h in kv.items():
            self.state.kv_headroom[tier] = float(h)

    def observe_replica_loads(self, replicas: Dict[str, List[float]]) -> None:
        """Per-replica occupancy vectors (instantaneous, not smoothed — the
        tier-level EWMA in ``observe_load`` already smooths the aggregate;
        the raw spread is the imbalance signal)."""
        for tier, reps in replicas.items():
            self.state.replica_loads[tier] = [float(x) for x in reps]

    def observe_health(self, health: Dict[str, str]) -> None:
        """Circuit-breaker states (exact, not smoothed — the monitor's
        EWMA already did the smoothing)."""
        for tier, s in health.items():
            self.state.health[tier] = str(s)

    def observe_latency(self, seconds: float) -> None:
        self._lat_window.append(float(seconds))

    def observe_acceptance(self, rate: float) -> None:
        """Speculative-decoding acceptance-rate feedback (accepted/drafted
        of one finished verify loop), EWMA-smoothed like the loads."""
        rate = min(max(float(rate), 0.0), 1.0)
        prev = self.state.spec_accept
        if prev is None:
            self.state.spec_accept = rate
            return
        a = self.alpha
        self.state.spec_accept = (1 - a) * prev + a * rate

    def p95_latency(self) -> float:
        if not self._lat_window:
            return 0.0
        xs = sorted(self._lat_window)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    def snapshot(self) -> SystemState:
        s = self.state
        snap = SystemState(bandwidth_bps=s.bandwidth_bps,
                           loads=dict(s.loads),
                           queue_depths=dict(s.queue_depths),
                           bandwidths=dict(s.bandwidths))
        snap.parked_sessions = dict(s.parked_sessions)
        snap.kv_headroom = dict(s.kv_headroom)
        snap.health = dict(s.health)
        snap.replica_loads = {t: list(v) for t, v in s.replica_loads.items()}
        snap.spec_accept = s.spec_accept
        return snap
