"""System-state tracking for the offloading policy: s = (ℓ, b) of Eq. 5/6.

EWMA estimators over observed edge load and link bandwidth; the scheduler
feeds observations in, the policy reads smoothed state out.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Deque, Optional
import collections


@dataclass
class SystemState:
    edge_load: float = 0.0        # ℓ ∈ [0,1]: edge utilization
    bandwidth_bps: float = 300e6  # b: available edge<->cloud bandwidth
    cloud_load: float = 0.0
    queue_depth_edge: int = 0
    queue_depth_cloud: int = 0


class StateEstimator:
    """EWMA smoothing of raw observations (load spikes shouldn't thrash τ)."""

    def __init__(self, alpha: float = 0.2,
                 init: Optional[SystemState] = None):
        self.alpha = alpha
        self.state = init or SystemState()
        self._lat_window: Deque[float] = collections.deque(maxlen=256)

    def observe_edge_load(self, load: float) -> None:
        a = self.alpha
        self.state.edge_load = (1 - a) * self.state.edge_load + a * float(load)

    def observe_cloud_load(self, load: float) -> None:
        a = self.alpha
        self.state.cloud_load = (1 - a) * self.state.cloud_load + a * float(load)

    def observe_bandwidth(self, bps: float) -> None:
        a = self.alpha
        self.state.bandwidth_bps = ((1 - a) * self.state.bandwidth_bps
                                    + a * float(bps))

    def observe_queues(self, edge: int, cloud: int) -> None:
        self.state.queue_depth_edge = edge
        self.state.queue_depth_cloud = cloud

    def observe_latency(self, seconds: float) -> None:
        self._lat_window.append(float(seconds))

    def p95_latency(self) -> float:
        if not self._lat_window:
            return 0.0
        xs = sorted(self._lat_window)
        return xs[min(len(xs) - 1, int(0.95 * len(xs)))]

    def snapshot(self) -> SystemState:
        return SystemState(self.state.edge_load, self.state.bandwidth_bps,
                           self.state.cloud_load, self.state.queue_depth_edge,
                           self.state.queue_depth_cloud)
