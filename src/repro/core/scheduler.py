"""The MoA-Off scheduler: modality-aware scoring + adaptive routing.

This is the control plane the paper contributes. It owns
  · the modality-aware module (Pallas-kernel-backed complexity scoring),
  · the offloading policy π (Eq. 6, pluggable — baselines share the interface),
  · the EWMA system-state estimator,
and exposes ``route(request)`` to the serving engine / simulator.
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, List, Optional

import numpy as np

import dataclasses

from repro.config import ComplexityConfig, PolicyConfig, SpecConfig
from repro.core import complexity as cx
from repro.core.policy import OffloadingPolicy
from repro.core.request import Decision, ModalityInput, Request
from repro.core.state import StateEstimator, SystemState


class MoAOffScheduler:
    def __init__(self, policy: Optional[OffloadingPolicy] = None,
                 complexity_cfg: ComplexityConfig = ComplexityConfig(),
                 policy_cfg: PolicyConfig = PolicyConfig(),
                 use_kernel: bool = True,
                 spec: Optional[SpecConfig] = None):
        self.policy = policy or OffloadingPolicy(policy_cfg)
        self.cc = complexity_cfg
        self.estimator = StateEstimator()
        self.use_kernel = use_kernel
        # cross-tier speculative decoding: when set, requests whose fused
        # generation lands on spec.target_tier are additionally stamped to
        # draft on spec.draft_tier — the third choice next to local/offload
        self.spec = spec
        self.score_time_s = 0.0  # cumulative modality-module cost (overhead claim)
        self.n_scored = 0

    # -- modality-aware module ------------------------------------------------

    def score(self, request: Request) -> Dict[str, float]:
        """Complexity per modality. Uses real payloads when present, else the
        metadata counts the data pipeline attached (same formulas)."""
        t0 = time.perf_counter()
        scores: Dict[str, float] = {}
        for name, mod in request.modalities.items():
            if mod.complexity is not None:
                scores[name] = float(mod.complexity)
                continue
            if mod.kind == "image":
                if mod.data is not None:
                    img = np.asarray(mod.data, np.float32)[None]
                    out = cx.image_complexity(img, self.cc,
                                              use_kernel=self.use_kernel)
                    scores[name] = float(out["c_img"][0])
                else:
                    h = mod.meta.get("h", 512)
                    w = mod.meta.get("w", 512)
                    base = min(1.0, (h * w) / (self.cc.ref_h * self.cc.ref_w))
                    scores[name] = (self.cc.w_res * base
                                    + (1 - self.cc.w_res)
                                    * mod.meta.get("content_c", 0.5))
            elif mod.kind == "text":
                out = cx.text_complexity_from_counts(
                    mod.meta.get("tokens", 0), mod.meta.get("entities", 0),
                    mod.meta.get("sentences", 1), self.cc)
                scores[name] = float(out["c_text"])
            elif mod.kind == "audio":
                if mod.data is not None:
                    out = cx.audio_complexity(np.asarray(mod.data)[None], self.cc)
                    scores[name] = float(out["c_audio"][0])
                else:
                    scores[name] = float(mod.meta.get("content_c", 0.5))
            mod.complexity = scores[name]
        self.score_time_s += time.perf_counter() - t0
        self.n_scored += 1
        return scores

    # -- routing ---------------------------------------------------------------

    def route(self, request: Request,
              state: Optional[SystemState] = None) -> Decision:
        scores = self.score(request)
        st = state or self.estimator.snapshot()
        decision = self.policy.decide(request, scores, st)
        if self.spec is not None:
            decision = self._maybe_speculate(decision, st)
        self.policy.update(st)
        return decision

    def _maybe_speculate(self, decision: Decision,
                         st: SystemState) -> Decision:
        """Third routing choice next to {local, offload}: when the fused
        generation lands on the SpecConfig target tier and the acceptance
        EWMA clears ``min_accept``, stamp draft-and-verify onto the
        decision. Lives here (not in the policies) so the ablation
        baselines keep deciding exactly as before."""
        sp = self.spec
        topo = getattr(self.policy, "topology", None)
        if topo is None:
            return decision
        try:
            fusion = topo.fusion_tier(decision.routes)
        except KeyError:
            return decision
        if fusion != sp.target_tier:
            return decision  # generation isn't on the target: no verify
        alpha = st.spec_accept if st.spec_accept is not None else (
            sp.init_accept)
        if alpha < sp.min_accept:
            return decision  # drafts are being rejected: plain offload
        return dataclasses.replace(
            decision,
            speculate=(sp.draft_tier, sp.target_tier, sp.draft_k, alpha),
            reason=decision.reason + "+speculate")

    # -- feedback from the runtime (simulator / live server) -------------------

    def observe(self, *, loads: Optional[Dict[str, float]] = None,
                queue_depths: Optional[Dict[str, int]] = None,
                bandwidths: Optional[Dict[str, float]] = None,
                bandwidth_bps: Optional[float] = None,
                latency_s: Optional[float] = None,
                parked: Optional[Dict[str, int]] = None,
                kv: Optional[Dict[str, float]] = None,
                health: Optional[Dict[str, str]] = None,
                replicas: Optional[Dict[str, List[float]]] = None,
                acceptance: Optional[float] = None,
                edge_load: Optional[float] = None,
                cloud_load: Optional[float] = None) -> None:
        """Feed one batch of system observations into the EWMA estimator.

        The API is dict-based and keyed by tier name: ``loads`` /
        ``queue_depths`` / per-remote-tier ``bandwidths``, plus the scalar
        Eq. 5 WAN ``bandwidth_bps`` and per-request ``latency_s`` feedback.
        ``parked`` is the cache-affinity signal: parked multi-turn sessions
        per tier, whose next turns will route sticky to that tier. ``kv``
        is the per-tier KV-pool headroom (free page fraction) — real memory
        pressure, finer-grained than slot occupancy. ``replicas`` carries
        the per-replica occupancy vectors of replicated tiers (raw, the
        spread is the imbalance signal the tier-level EWMA hides).
        ``edge_load=`` / ``cloud_load=`` are a deprecated two-tier shim kept
        for out-of-tree callers; they fold into ``loads``.
        """
        if edge_load is not None or cloud_load is not None:
            warnings.warn(
                "MoAOffScheduler.observe(edge_load=..., cloud_load=...) is "
                "deprecated; pass loads={'edge': ..., 'cloud': ...} instead",
                DeprecationWarning, stacklevel=2)
            loads = dict(loads or {})
            if edge_load is not None:
                loads.setdefault("edge", edge_load)
            if cloud_load is not None:
                loads.setdefault("cloud", cloud_load)
        if loads:
            for tier, load in loads.items():
                self.estimator.observe_load(tier, load)
        if queue_depths:
            self.estimator.observe_queue_depths(queue_depths)
        if parked:
            self.estimator.observe_parked_sessions(parked)
        if kv:
            self.estimator.observe_kv_headroom(kv)
        if health:
            self.estimator.observe_health(health)
        if replicas:
            self.estimator.observe_replica_loads(replicas)
        if bandwidth_bps is not None:
            self.estimator.observe_bandwidth(bandwidth_bps)
        if bandwidths:
            for tier, bps in bandwidths.items():
                self.estimator.observe_bandwidth(bps, tier=tier)
        if acceptance is not None:
            self.estimator.observe_acceptance(acceptance)
        if latency_s is not None:
            self.estimator.observe_latency(latency_s)
            if hasattr(self.policy, "feedback"):
                self.policy.feedback(latency_s)

    def mean_score_cost_s(self) -> float:
        return self.score_time_s / max(self.n_scored, 1)
