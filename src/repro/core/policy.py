"""§3.2 Adaptive Edge-Cloud Collaborative Offloading — Eq. 5 and Eq. 6,
generalized to an N-tier cluster topology.

``decide_modality`` is the literal Eq. 5; ``OffloadingPolicy`` is the full
π(c_1..c_k, s) with per-modality thresholds and (beyond the paper's static
τ=0.5) an adaptive-τ controller driven by the EWMA system state, implementing
the paper's "integrates modality-aware thresholds with system-level dynamics".

Tier selection is two-stage: per-tier Eq. 5 eligibility (local tiers gate on
complexity + load + bandwidth exactly as in the paper; remote tiers gate on a
capability-scaled complexity threshold), then a cost-model-informed argmin
over the eligible set. On the default two-tier topology this reduces exactly
to the paper's binary edge/cloud rule.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

from repro.config import (ClusterTopology, PolicyConfig, TierSpec,
                          two_tier_topology)
from repro.core.request import Decision, Request
from repro.core.state import SystemState

EDGE, CLOUD = "edge", "cloud"


def decide_modality(c: float, tau: float, state: SystemState,
                    pol: PolicyConfig, load: Optional[float] = None) -> str:
    """Eq. 5 for one modality.

    Literal form: edge iff  c <= τ  ∧  ℓ <= ℓ_max  ∧  b <= β.
    Corrected form (paper_faithful_bandwidth=False): the bandwidth term
    instead gates CLOUD eligibility — offloading needs b >= β_min, otherwise
    the transfer would dominate and the edge keeps the work.

    ``load`` overrides the gated utilization (defaults to the edge tier's);
    the N-tier policy calls this once per local tier with that tier's ℓ.
    """
    ell = state.edge_load if load is None else load
    load_ok = ell <= pol.edge_load_max
    if pol.paper_faithful_bandwidth:
        bw_ok = state.bandwidth_bps <= pol.bandwidth_beta
        return EDGE if (c <= tau and load_ok and bw_ok) else CLOUD
    cloud_feasible = state.bandwidth_bps >= pol.bandwidth_beta * 0.1
    if c <= tau and load_ok:
        return EDGE
    return CLOUD if cloud_feasible else EDGE


def tier_cost_estimate(tier: TierSpec, request: Optional[Request],
                       modality: str, state: SystemState) -> float:
    """Queue-aware service + transfer latency estimate for one modality on
    one tier, from the analytic cost model over the tier's real model."""
    from repro.configs import get_config  # local imports, no cycle
    from repro.serving import cost_model as cm

    mcfg = get_config(tier.model)  # memoized in the config registry
    mod = request.modalities.get(modality) if request is not None else None
    if mod is not None:
        toks = cm.modality_tokens(mcfg, mod)
        img_toks = toks if mod.kind == "image" else 0
        txt_toks = toks if mod.kind != "image" else 0
        size = mod.size_bytes
    else:  # score-only call sites (property tests): nominal text modality
        img_toks, txt_toks, size = 0, 64, 4096
    decode = request.decode_tokens if request is not None else 32
    costs = cm.request_phase_costs(mcfg, txt_toks, img_toks, decode, tier)
    sec = costs["prefill"].seconds + costs["decode"].seconds
    sec *= 1.0 + state.queue_depth(tier.name) / max(tier.servers, 1)
    if tier.is_remote:
        # observed per-tier bandwidth, falling back to the global scalar b —
        # a degraded link reprices the tier even when only b is tracked
        sec += cm.transfer_seconds(size, state.bandwidth_to(tier.name),
                                   tier.rtt_s)
    return sec


class OffloadingPolicy:
    """π(c_1, …, c_k, s) — Eq. 6 with adaptive thresholds over N tiers."""

    name = "moa-off"
    modality_aware = True
    uses_system_state = True

    def __init__(self, cfg: PolicyConfig = PolicyConfig(),
                 topology: Optional[ClusterTopology] = None):
        self.cfg = cfg
        self.topology = topology or two_tier_topology()
        # stamped onto every Decision so any_cloud/all_edge stay correct
        # for arbitrary tier names
        self.local_names = frozenset(
            t.name for t in self.topology.local_tiers)
        self.taus: Dict[str, float] = {
            "image": cfg.tau_image, "text": cfg.tau_text,
            "audio": cfg.tau_audio,
        }

    # -- tier selection -----------------------------------------------------

    def _argmin_tier(self, tiers: Sequence[TierSpec], request, modality: str,
                     state: SystemState) -> str:
        if len(tiers) == 1:  # two-tier fast path == legacy behavior
            return tiers[0].name
        return min(tiers, key=lambda t: tier_cost_estimate(
            t, request, modality, state)).name

    @staticmethod
    def _routable(tiers: Sequence[TierSpec],
                  state: SystemState) -> Sequence[TierSpec]:
        """Drop circuit-open (quarantined/probing) tiers from a candidate
        pool. Falls back to the unfiltered pool when the breaker excluded
        everything — the runtime's degraded-routing layer then decides, so
        the policy never deadlocks on an all-quarantined cluster (and with
        the health layer off the pool passes through untouched)."""
        ok = [t for t in tiers if state.healthy(t.name)]
        return ok or tiers

    def _route_modality(self, request, modality: str, c: float, tau: float,
                        state: SystemState) -> str:
        topo = self.topology
        eligible_local = [
            t for t in self._routable(topo.local_tiers, state)
            if decide_modality(c, tau, state, self.cfg,
                               load=state.load(t.name)) == EDGE]
        if eligible_local:
            return self._argmin_tier(eligible_local, request, modality, state)
        remotes = self._routable(topo.remote_tiers, state)
        if not remotes:  # pure-edge cluster: least-loaded local keeps it
            pool = self._routable(topo.local_tiers, state)
            return min(pool, key=lambda t: state.load(t.name)).name
        # capability-scaled Eq. 5 threshold: a tier of capability κ accepts
        # complexity up to τ + (1-τ)κ — the cloud (κ=1) accepts everything
        eligible = [t for t in remotes
                    if c <= tau + (1.0 - tau) * t.capability]
        if not eligible:
            fallback = [t for t in remotes
                        if t.name == topo.default_remote.name]
            return (fallback or remotes)[0].name
        return self._argmin_tier(eligible, request, modality, state)

    def decide(self, request: Request, scores: Dict[str, float],
               state: SystemState) -> Decision:
        routes = {}
        for modality, c in scores.items():
            tau = self.taus.get(modality, 0.5)
            routes[modality] = self._route_modality(request, modality,
                                                    float(c), tau, state)
        return Decision(routes=routes, taus=dict(self.taus),
                        reason=f"eq5 load={state.edge_load:.2f}",
                        local_tiers=self.local_names)

    def update(self, state: SystemState) -> None:
        """Adaptive-τ controller (collaborative scheduling): balance the
        tier queues — a deep local backlog sheds work outward (τ down),
        a deep remote backlog pulls work back (τ up). At steady moderate
        load this sits at the static τ; under bursts/failures it
        re-balances."""
        if not self.cfg.adaptive_tau:
            return
        topo = self.topology
        local = {t.name for t in topo.local_tiers}
        qe = sum(d for t, d in state.queue_depths.items() if t in local)
        qc = sum(d for t, d in state.queue_depths.items() if t not in local)
        max_local_load = max((state.load(t.name) for t in topo.local_tiers),
                             default=state.edge_load)
        imbalance = (qe - qc) / (qe + qc + 4.0)
        if abs(imbalance) < 0.25 and max_local_load <= self.cfg.edge_load_max:
            return
        delta = -self.cfg.tau_step if (imbalance > 0 or
                                       max_local_load > self.cfg.edge_load_max
                                       ) else self.cfg.tau_step
        for m in self.taus:
            self.taus[m] = min(0.95, max(0.05, self.taus[m] + delta))


class NoCollabPolicy(OffloadingPolicy):
    """Ablation §4.3(b): modality-aware but ignores system state entirely."""

    name = "moa-off-no-collab"
    uses_system_state = False

    def decide(self, request, scores, state):
        frozen = SystemState(edge_load=0.0,
                             bandwidth_bps=self.cfg.bandwidth_beta)
        d = super().decide(request, scores, frozen)
        return dataclasses.replace(d, reason="static")

    def update(self, state):  # no adaptation either
        return


class NoModalityAwarePolicy(OffloadingPolicy):
    """Ablation §4.3(a): the modality-aware module is REMOVED — no complexity
    scores exist, so the scheduler can only route on system state (keep work
    on the anchor local tier while it has headroom, spill outward
    otherwise). Hard and easy inputs are treated identically."""

    name = "moa-off-no-modality"
    modality_aware = False

    def decide(self, request, scores, state):
        anchor = self.topology.default_local
        load_ok = state.load(anchor.name) <= self.cfg.edge_load_max
        route = anchor.name if load_ok else self.topology.default_remote.name
        return Decision(routes={m: route for m in scores},
                        taus=dict(self.taus), reason="state-only",
                        local_tiers=self.local_names)

    def update(self, state):  # no complexity signal -> nothing to adapt
        return
