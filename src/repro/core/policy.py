"""§3.2 Adaptive Edge-Cloud Collaborative Offloading — Eq. 5 and Eq. 6.

``decide_modality`` is the literal Eq. 5; ``OffloadingPolicy`` is the full
π(c_1..c_k, s) with per-modality thresholds and (beyond the paper's static
τ=0.5) an adaptive-τ controller driven by the EWMA system state, implementing
the paper's "integrates modality-aware thresholds with system-level dynamics".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.config import PolicyConfig
from repro.core.request import Decision, Request
from repro.core.state import SystemState

EDGE, CLOUD = "edge", "cloud"


def decide_modality(c: float, tau: float, state: SystemState,
                    pol: PolicyConfig) -> str:
    """Eq. 5 for one modality.

    Literal form: edge iff  c <= τ  ∧  ℓ <= ℓ_max  ∧  b <= β.
    Corrected form (paper_faithful_bandwidth=False): the bandwidth term
    instead gates CLOUD eligibility — offloading needs b >= β_min, otherwise
    the transfer would dominate and the edge keeps the work.
    """
    load_ok = state.edge_load <= pol.edge_load_max
    if pol.paper_faithful_bandwidth:
        bw_ok = state.bandwidth_bps <= pol.bandwidth_beta
        return EDGE if (c <= tau and load_ok and bw_ok) else CLOUD
    cloud_feasible = state.bandwidth_bps >= pol.bandwidth_beta * 0.1
    if c <= tau and load_ok:
        return EDGE
    return CLOUD if cloud_feasible else EDGE


class OffloadingPolicy:
    """π(c_1, …, c_k, s) — Eq. 6 with adaptive thresholds."""

    name = "moa-off"
    modality_aware = True
    uses_system_state = True

    def __init__(self, cfg: PolicyConfig = PolicyConfig()):
        self.cfg = cfg
        self.taus: Dict[str, float] = {
            "image": cfg.tau_image, "text": cfg.tau_text,
            "audio": cfg.tau_audio,
        }

    def decide(self, request: Request, scores: Dict[str, float],
               state: SystemState) -> Decision:
        routes = {}
        for modality, c in scores.items():
            tau = self.taus.get(modality, 0.5)
            routes[modality] = decide_modality(float(c), tau, state, self.cfg)
        return Decision(routes=routes, taus=dict(self.taus),
                        reason=f"eq5 load={state.edge_load:.2f}")

    def update(self, state: SystemState) -> None:
        """Adaptive-τ controller (collaborative scheduling): balance the
        tier queues — a deep edge backlog sheds work to the cloud (τ down),
        a deep cloud backlog pulls work back (τ up). At steady moderate load
        this sits at the static τ; under bursts/failures it re-balances."""
        if not self.cfg.adaptive_tau:
            return
        qe, qc = state.queue_depth_edge, state.queue_depth_cloud
        imbalance = (qe - qc) / (qe + qc + 4.0)
        if abs(imbalance) < 0.25 and state.edge_load <= self.cfg.edge_load_max:
            return
        delta = -self.cfg.tau_step if (imbalance > 0 or
                                       state.edge_load > self.cfg.edge_load_max
                                       ) else self.cfg.tau_step
        for m in self.taus:
            self.taus[m] = min(0.95, max(0.05, self.taus[m] + delta))


class NoCollabPolicy(OffloadingPolicy):
    """Ablation §4.3(b): modality-aware but ignores system state entirely."""

    name = "moa-off-no-collab"
    uses_system_state = False

    def decide(self, request, scores, state):
        frozen = SystemState(edge_load=0.0,
                             bandwidth_bps=self.cfg.bandwidth_beta)
        routes = {m: decide_modality(float(c), self.taus.get(m, 0.5), frozen,
                                     self.cfg)
                  for m, c in scores.items()}
        return Decision(routes=routes, taus=dict(self.taus), reason="static")

    def update(self, state):  # no adaptation either
        return


class NoModalityAwarePolicy(OffloadingPolicy):
    """Ablation §4.3(a): the modality-aware module is REMOVED — no complexity
    scores exist, so the scheduler can only route on system state (keep work
    on the edge while it has headroom, spill to the cloud otherwise). Hard
    and easy inputs are treated identically."""

    name = "moa-off-no-modality"
    modality_aware = False

    def decide(self, request, scores, state):
        load_ok = state.edge_load <= self.cfg.edge_load_max
        route = EDGE if load_ok else CLOUD
        return Decision(routes={m: route for m in scores},
                        taus=dict(self.taus), reason="state-only")

    def update(self, state):  # no complexity signal -> nothing to adapt
        return
