"""§3.1 Lightweight Heterogeneous Modality-Aware module.

Image complexity (§3.1.1): weighted sum of resolution / edge-density /
entropy-texture / sharpness indicators, computed by the fused Pallas kernel
(``repro.kernels``) with a pure-jnp fallback oracle.

Text complexity (§3.1.2): token-length + entity-density terms over the toy
tokenizer's token classes.

Audio complexity (beyond-paper extension, same recipe): frame count +
spectral-flux + frame-entropy over precomputed mel frames — lets the MoA-Off
policy route the audio modality of whisper-family requests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ComplexityConfig


# ---------------------------------------------------------------------------
# image (kernel-backed)
# ---------------------------------------------------------------------------


def image_complexity(imgs: jax.Array,
                     cc: ComplexityConfig = ComplexityConfig(),
                     use_kernel: bool = True,
                     interpret: Optional[bool] = None) -> Dict[str, jax.Array]:
    """imgs: (B, H, W) float32 in [0,255] -> dict incl. ``c_img`` (B,)."""
    from repro.kernels import ops as kops
    from repro.kernels import ref as kref

    if use_kernel:
        stats = kops.image_stats(imgs, interpret=interpret)
    else:
        stats = kref.image_stats_batch_ref(imgs)
    return kops.image_complexity_from_stats(stats, imgs.shape[1],
                                            imgs.shape[2], cc)


def calibrate_percentiles(imgs: jax.Array,
                          cc: ComplexityConfig = ComplexityConfig()
                          ) -> ComplexityConfig:
    """Fit the P5/P95 normalizers (Eq. 2 & 4) on a calibration set."""
    from repro.kernels import ops as kops

    stats = kops.image_stats(imgs)
    n = imgs.shape[1] * imgs.shape[2]
    g = np.asarray(stats["sobel_sum"]) / n
    lm = np.asarray(stats["lap_sum"]) / n
    lv = np.asarray(stats["lap_sq_sum"]) / n - lm ** 2
    import dataclasses

    return dataclasses.replace(
        cc,
        edge_p5=float(np.percentile(g, 5)),
        edge_p95=float(np.percentile(g, 95)),
        lap_p5=float(np.percentile(lv, 5)),
        lap_p95=float(np.percentile(lv, 95)),
    )


# ---------------------------------------------------------------------------
# text
# ---------------------------------------------------------------------------


def text_complexity_from_counts(num_tokens, num_entities, num_sentences,
                                cc: ComplexityConfig = ComplexityConfig()):
    """Vectorized §3.1.2. All args (B,) arrays or scalars."""
    num_tokens = jnp.asarray(num_tokens, jnp.float32)
    num_entities = jnp.asarray(num_entities, jnp.float32)
    num_sentences = jnp.maximum(jnp.asarray(num_sentences, jnp.float32), 1.0)
    c_len = jnp.minimum(1.0, num_tokens / cc.len_l0)
    c_ner = jnp.minimum(1.0, (num_entities / num_sentences) / cc.ner_gamma)
    c_text = cc.beta_len * c_len + cc.beta_ner * c_ner
    return {"c_len": c_len, "c_ner": c_ner, "c_text": c_text}


def text_complexity_from_tokens(tokens: jax.Array, pad_id: int,
                                entity_mask: jax.Array,
                                sentence_end_mask: jax.Array,
                                cc: ComplexityConfig = ComplexityConfig()):
    """tokens (B, L) + per-token class masks -> §3.1.2 scores.

    ``entity_mask``/``sentence_end_mask``: bool (B, L), the toy tokenizer's
    entity/numeral and sentence-terminator classes (stands in for NER).
    """
    valid = tokens != pad_id
    n_tok = jnp.sum(valid, axis=-1)
    n_ent = jnp.sum(entity_mask & valid, axis=-1)
    n_sent = jnp.sum(sentence_end_mask & valid, axis=-1)
    return text_complexity_from_counts(n_tok, n_ent, n_sent, cc)


# ---------------------------------------------------------------------------
# audio (beyond-paper, same single-pass recipe)
# ---------------------------------------------------------------------------


def audio_complexity(frames: jax.Array,
                     cc: ComplexityConfig = ComplexityConfig()):
    """frames: (B, T, F) precomputed mel features -> dict incl ``c_audio``.

    Indicators: duration scale (T/T0), spectral flux (mean |Δframe|,
    squashed), frame-entropy (energy distribution across mel bins).
    """
    frames = frames.astype(jnp.float32)
    b, t, f = frames.shape
    c_dur = jnp.minimum(1.0, t / float(cc.audio_ref_frames))
    flux = jnp.mean(jnp.abs(jnp.diff(frames, axis=1)), axis=(1, 2))
    c_flux = 1.0 - jnp.exp(-flux)
    e = jnp.maximum(frames - frames.min(axis=(1, 2), keepdims=True), 1e-9)
    p = e / jnp.sum(e, axis=2, keepdims=True)
    ent = -jnp.mean(jnp.sum(p * jnp.log(p), axis=2), axis=1) / jnp.log(f)
    c_audio = (c_dur + c_flux + ent) / 3.0
    return {"c_dur": jnp.broadcast_to(c_dur, (b,)), "c_flux": c_flux,
            "c_ent": ent, "c_audio": c_audio}
