"""Baseline offloading policies from §4.1: Cloud-only, Edge-only, PerLLM.

PerLLM [arXiv:2405.14636] is a personalized edge-cloud scheduler for LLM
services: per-REQUEST (uniform, modality-blind) decisions from service-level
constraints and system state, via a constrained upper-confidence-bound
selection. We implement its decision structure faithfully at the level the
comparison needs: request-granularity routing from (request size, SLO,
edge load, bandwidth), with a UCB exploration term across the two "arms" —
but with NO per-modality complexity awareness (that is MoA-Off's delta).
"""
from __future__ import annotations

import math
from typing import Dict

from repro.config import PolicyConfig
from repro.core.policy import CLOUD, EDGE, OffloadingPolicy
from repro.core.request import Decision, Request
from repro.core.state import SystemState


class CloudOnlyPolicy(OffloadingPolicy):
    name = "cloud-only"
    modality_aware = False
    uses_system_state = False

    def decide(self, request, scores, state):
        cloud = self.topology.default_remote.name
        return Decision(routes={m: cloud for m in scores},
                        reason="cloud-only", local_tiers=self.local_names)

    def update(self, state):
        return


class EdgeOnlyPolicy(OffloadingPolicy):
    name = "edge-only"
    modality_aware = False
    uses_system_state = False

    def decide(self, request, scores, state):
        edge = self.topology.default_local.name
        return Decision(routes={m: edge for m in scores}, reason="edge-only",
                        local_tiers=self.local_names)

    def update(self, state):
        return


class PerLLMPolicy(OffloadingPolicy):
    """Uniform per-request scheduling: constraint-satisfaction + cost
    minimization, as in PerLLM [arXiv:2405.14636].

    PerLLM picks the CHEAPEST deployment that is predicted to satisfy the
    request's SLO: the edge costs (energy/$) far less than the cloud, so
    requests stay on the edge while the queue-predicted latency remains
    inside the SLO margin, and spill to the cloud otherwise — with NO
    awareness of per-modality complexity (that is MoA-Off's delta). A small
    UCB term explores the margin estimate online.
    """

    name = "perllm"
    modality_aware = False

    def __init__(self, cfg: PolicyConfig = PolicyConfig(), topology=None,
                 slo_margin: float = 0.20, edge_service_est: float = 0.8,
                 explore_eps: float = 0.28, refresh_s: float = 12.0,
                 seed: int = 17):
        super().__init__(cfg, topology)
        import numpy as _np
        self.slo_margin = slo_margin
        self.svc_est = edge_service_est  # EWMA-updated from feedback
        self.eps = explore_eps  # bandit exploration (the original is a UCB)
        self._rng = _np.random.default_rng(seed)
        self.refresh_s = refresh_s  # scheduling-loop period (stale between)
        self.t = 1
        self._pending_arm = None
        self._last_refresh = -1e9
        self._cached_queue = 0

    def decide(self, request: Request, scores: Dict[str, float],
               state: SystemState) -> Decision:
        self.t += 1
        edge = self.topology.default_local.name
        cloud = self.topology.default_remote.name
        # per-service scheduling loop: PerLLM re-plans periodically, not per
        # request — between refreshes it routes on the cached queue estimate
        if request.arrival_s - self._last_refresh >= self.refresh_s:
            self._cached_queue = state.queue_depth(edge)
            self._last_refresh = request.arrival_s
        pred_edge = (self._cached_queue + 1) * self.svc_est
        budget = self.slo_margin * request.slo_s
        big = request.total_bytes() > 0.45e6  # payload constraint -> cloud
        if big and state.bandwidth_bps >= 100e6:
            arm = cloud
        elif pred_edge <= budget:
            arm = edge  # cheapest feasible deployment
        else:
            arm = cloud
        if self._rng.random() < self.eps:  # bandit exploration step
            arm = edge if arm == cloud else cloud
        self._pending_arm = arm
        return Decision(routes={m: arm for m in scores},
                        reason=f"perllm-{arm} pred={pred_edge:.2f}",
                        local_tiers=self.local_names)

    def feedback(self, latency_s: float) -> None:
        if self._pending_arm == self.topology.default_local.name:
            # crude online service estimate (keeps the predictor honest)
            self.svc_est = 0.95 * self.svc_est + 0.05 * min(latency_s, 2.0)
        self._pending_arm = None

    def update(self, state):
        return


def make_policy(name: str, cfg: PolicyConfig = PolicyConfig(),
                topology=None):
    from repro.core.policy import (NoCollabPolicy, NoModalityAwarePolicy,
                                   OffloadingPolicy)

    table = {
        "moa-off": OffloadingPolicy,
        "cloud-only": CloudOnlyPolicy,
        "edge-only": EdgeOnlyPolicy,
        "perllm": PerLLMPolicy,
        "moa-off-no-modality": NoModalityAwarePolicy,
        "moa-off-no-collab": NoCollabPolicy,
    }
    return table[name](cfg, topology)
