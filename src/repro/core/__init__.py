"""The paper's primary contribution: modality-aware complexity estimation
(§3.1) + adaptive edge-cloud offloading (§3.2)."""
from repro.core.complexity import (audio_complexity, image_complexity,  # noqa
                                   text_complexity_from_counts,
                                   text_complexity_from_tokens,
                                   calibrate_percentiles)
from repro.core.policy import (EDGE, CLOUD, OffloadingPolicy,  # noqa
                               NoCollabPolicy, NoModalityAwarePolicy,
                               decide_modality)
from repro.core.baselines import (CloudOnlyPolicy, EdgeOnlyPolicy,  # noqa
                                  PerLLMPolicy, make_policy)
from repro.core.request import (Decision, ModalityInput, Outcome,  # noqa
                                Request)
from repro.core.scheduler import MoAOffScheduler  # noqa
from repro.core.state import StateEstimator, SystemState  # noqa
